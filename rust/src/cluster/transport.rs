//! Cluster wire protocol: JSON control lines plus `spdnn-clu1` binary
//! data frames, over TCP between rank 0 and the worker ranks.
//!
//! Two encodings share one stream and are distinguished by the first
//! byte of each message (a JSON object always opens with `{`, a binary
//! frame with the magic `S` of `"SCL1"`):
//!
//! * **JSON lines** carry the low-rate control verbs — exactly the
//!   framing the serving subsystem speaks (`server::protocol`):
//!
//!   ```text
//!   {"op":"hello","wire":"bin"}                     connect-time negotiation
//!   {"op":"ping"}                                   liveness
//!   {"op":"load","rank":R,"model":{...},"spec":{...},"prune":true}
//!                                                   replicate the weights
//!   {"op":"shard","start":S,"features":[...]}       scatter (JSON wire)
//!   {"op":"shard-begin","start":S,"rows":R,"chunks":C}
//!                                                   open a chunked scatter
//!   {"op":"metrics"}                                telemetry pull (v5)
//!   {"op":"shutdown"}                               drain + exit
//!   ```
//!
//! * **`spdnn-clu1` frames** carry the high-rate data payloads when the
//!   binary wire is negotiated — `data::binio`'s packed little-endian
//!   layout behind a length prefix:
//!
//!   ```text
//!   ┌──────┬──────┬─────────┬──────────────────────────────┐
//!   │"SCL1"│ kind │ u32 len │ payload (len bytes, LE)      │
//!   │ 4 B  │ 1 B  │  4 B    │                              │
//!   └──────┴──────┴─────────┴──────────────────────────────┘
//!   kind 1  shard        u64 start | u64 n | panel
//!   kind 3  shard-chunk  u64 index | u64 start | u64 n | panel
//!   kind 4  result       u64 rank,start,count,ncats,nacts,nlive,
//!                        nsecs,edges | f64 secs | u64×ncats cats |
//!                        f32×nacts activations | u64×nlive live |
//!                        f64×nsecs layer_secs
//!   kind 5  shard (traced, v3)   u64 trace | kind-1 payload
//!   kind 6  result (traced, v3)  u64 trace | u64 nspan | span JSON
//!                                (nspan bytes) | kind-4 payload
//!   kind 7  exchange (v4)  u64 trace | u64 layer | u64 n | panel
//!   kind 8  partial (v4)   u64 rank | u64 layer | u64 count |
//!                          u64 n | f64 secs | panel
//!
//!   panel := u8 0 | f32×n                       dense
//!          | u8 1 | f32 v | bitmap ⌈n/8⌉ B      sparse-uniform
//!   ```
//!
//!   A panel whose values are all +0.0 or one shared bit pattern `v`
//!   (the challenge's thresholded {0,1} images — i.e. essentially every
//!   scatter) ships as a bitmap plus a single f32: ~1 bit per value
//!   instead of the 4 bytes of dense f32 or the ~4 characters of JSON.
//!   Arbitrary panels fall back to dense, still 3-5× tighter than JSON
//!   for real-valued data.
//!
//! **Negotiation**: the coordinator opens every connection with a
//! `hello` proposing a [`WireFormat`]; the worker echoes it together
//! with its protocol version, so skewed binaries fail with a clear
//! diagnostic instead of a parse error deep inside load/shard. Workers
//! answer each request in the encoding it arrived in (a chunked
//! scatter's result replies in the encoding of its chunk frames),
//! which keeps the reader side stateless.
//!
//! **Trace context (v3)**: scatters may carry an [`TraceId`] so one
//! served request stitches coordinator and rank spans into a single
//! end-to-end trace (`obs`). On the JSON wire it is an optional
//! `"trace"` hex field on `shard` / `shard-begin`, and results answer
//! with `"trace"` plus a `"spans"` array; on the binary wire the traced
//! frame kinds 5/6 wrap the v2 payloads. The untraced kinds 1/3/4 are
//! byte-identical to protocol v2, and the coordinator only emits traced
//! messages to peers whose hello answered version ≥ 3 — a v2 peer on
//! either wire keeps working, it just cannot contribute spans.
//!
//! **Weight-sharded partitioning (v4)**: a `load` may carry an optional
//! shard range (`shard_start`/`shard_count` on the JSON line) telling
//! the rank to hold only that contiguous row slice of every layer's
//! weights instead of a full replica. Inference then runs layer by
//! layer: the coordinator scatters the full live panel with an
//! `exchange` (kind 7 / `{"op":"exchange",...}`), each rank computes
//! its partial `[rows, count]` post-ReLU slice and answers with a
//! `partial` (kind 8 / `{"kind":"partial",...}`), and the coordinator
//! reassembles the next layer's panel — the all-to-all
//! boundary-activation exchange. Because an old worker's JSON parser
//! would silently ignore the unknown shard fields (and compute a full
//! replica), the coordinator refuses to run weights mode against peers
//! older than v4 instead of degrading.
//!
//! **Frame caps**: every read — JSON line or binary payload — is
//! bounded. Control traffic is capped at [`CONTROL_FRAME_CAP`]; once a
//! model is negotiated the cap widens to [`data_frame_cap`] (generous,
//! derived from the model width). One hostile or misbehaving peer can
//! no longer OOM a rank with a single giant line; it gets a protocol
//! error and the connection is dropped instead.
//!
//! Floats survive both wires bit-exactly: JSON widens `f32` to `f64`
//! and round-trips through shortest formatting; the binary frames carry
//! the raw little-endian bits. That equivalence is what keeps cluster
//! inference bit-identical to the single-process run on either wire.

use std::fmt;
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::NativeSpec;
use crate::data::binio::{put_f64, put_u64, write_f32s, ByteCursor};
use crate::engine::EngineKind;
use crate::obs::flight::{self, FlightEvent};
use crate::obs::trace::{spans_from_json, spans_to_json, SpanRecord, TraceId};
use crate::server::protocol::parse_f32_array;
use crate::util::config::RuntimeConfig;
use crate::util::json::Json;

/// v5 adds the telemetry pull (the `metrics` control verb: the rank's
/// Prometheus exposition plus its recent flight-recorder events, both
/// JSON lines on either wire); v4 added weight-sharded partitioning
/// (the optional shard range on `load` plus the exchange/partial frame
/// kinds 7/8); v3 added trace-context propagation (traced frame kinds
/// 5/6 and the optional JSON `trace`/`spans` fields). Older peers
/// negotiate down to the subset they speak — the untraced v2 frames
/// are byte-identical.
pub const CLUSTER_PROTOCOL_VERSION: i64 = 5;
/// Oldest protocol whose binary framing is a compatible subset of ours.
const CLUSTER_PROTOCOL_BIN_COMPAT: i64 = 2;
/// Oldest protocol that understands the traced encodings (frame kinds
/// 5/6, JSON `trace`/`spans` fields).
const CLUSTER_PROTOCOL_TRACE_MIN: i64 = 3;
/// Oldest protocol that understands weight-sharded partitioning (the
/// `load` shard range and frame kinds 7/8).
const CLUSTER_PROTOCOL_WEIGHTS_MIN: i64 = 4;
/// Oldest protocol that answers the `metrics` telemetry pull.
const CLUSTER_PROTOCOL_METRICS_MIN: i64 = 5;

/// Magic prefix of one `spdnn-clu1` binary frame.
pub const FRAME_MAGIC: &[u8; 4] = b"SCL1";
const FRAME_KIND_SHARD: u8 = 1;
const FRAME_KIND_SHARD_CHUNK: u8 = 3;
const FRAME_KIND_RESULT: u8 = 4;
const FRAME_KIND_SHARD_TRACED: u8 = 5;
const FRAME_KIND_RESULT_TRACED: u8 = 6;
const FRAME_KIND_EXCHANGE: u8 = 7;
const FRAME_KIND_PARTIAL: u8 = 8;
/// magic + kind + u32 payload length.
pub(crate) const FRAME_HEADER_BYTES: usize = 4 + 1 + 4;

/// Frame cap while no model is negotiated: control verbs are tiny, so
/// anything past this is hostile or corrupt.
pub const CONTROL_FRAME_CAP: usize = 4 << 20;
/// Ceiling no frame may exceed regardless of model size.
const FRAME_CAP_CEILING: usize = 2 << 30;

/// Per-connection frame cap once a model is known: generous — room for
/// a million-row feature shard serialized as JSON numbers (~32 bytes a
/// value) — but finite, so one unbounded line cannot OOM the process.
pub fn data_frame_cap(neurons: usize) -> usize {
    let per_row_json = neurons.saturating_mul(32);
    per_row_json.saturating_mul(1 << 20).clamp(CONTROL_FRAME_CAP, FRAME_CAP_CEILING)
}

/// Which encoding the data verbs (`shard`, `shard-chunk`, `result`)
/// travel in. Control verbs are JSON lines on both wires.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WireFormat {
    /// JSON number arrays (protocol v1's only encoding).
    Json,
    /// `spdnn-clu1` length-prefixed packed frames (the default).
    Bin,
}

impl WireFormat {
    pub fn parse(s: &str) -> Result<WireFormat> {
        match s {
            "json" => Ok(WireFormat::Json),
            "bin" => Ok(WireFormat::Bin),
            other => bail!("unknown wire format {other:?} (json|bin)"),
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            WireFormat::Json => "json",
            WireFormat::Bin => "bin",
        }
    }
}

impl fmt::Display for WireFormat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// The recipe a worker rank needs to materialise its full weight
/// replica: deterministic topology generation, not weight shipping.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelSpec {
    pub neurons: usize,
    pub layers: usize,
    pub k: usize,
    pub topology: String,
    pub seed: u64,
    /// Resolved bias constant (one value per neuron).
    pub bias: f64,
}

impl ModelSpec {
    pub fn from_config(cfg: &RuntimeConfig) -> ModelSpec {
        ModelSpec {
            neurons: cfg.neurons,
            layers: cfg.layers,
            k: cfg.k,
            topology: cfg.topology.clone(),
            seed: cfg.seed,
            bias: cfg.bias_value() as f64,
        }
    }

    /// Input edges of one full pass over `batch` features.
    pub fn input_edges(&self, batch: usize) -> u64 {
        batch as u64 * self.layers as u64 * (self.k as u64 * self.neurons as u64)
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("neurons", Json::Int(self.neurons as i64)),
            ("layers", Json::Int(self.layers as i64)),
            ("k", Json::Int(self.k as i64)),
            ("topology", Json::Str(self.topology.clone())),
            ("seed", Json::Int(self.seed as i64)),
            ("bias", Json::Num(self.bias)),
        ])
    }

    fn from_json(j: &Json) -> Result<ModelSpec> {
        Ok(ModelSpec {
            neurons: j.req_usize("neurons")?,
            layers: j.req_usize("layers")?,
            k: j.req_usize("k")?,
            topology: j.req_str("topology")?.to_string(),
            // The full u64 seed range round-trips through i64 bits (a
            // seed above i64::MAX serializes negative and casts back).
            seed: j
                .req("seed")?
                .as_i64()
                .ok_or_else(|| anyhow!("\"seed\" is not an integer"))?
                as u64,
            bias: j.req_f64("bias")?,
        })
    }
}

fn spec_to_json(spec: &NativeSpec) -> Json {
    Json::obj(vec![
        ("engine", Json::Str(spec.engine.as_str().to_string())),
        ("minibatch", Json::Int(spec.minibatch as i64)),
        ("slice", Json::Int(spec.slice as i64)),
        ("threads", Json::Int(spec.threads as i64)),
    ])
}

fn spec_from_json(j: &Json) -> Result<NativeSpec> {
    Ok(NativeSpec {
        engine: EngineKind::parse(j.req_str("engine")?)?,
        minibatch: j.req_usize("minibatch")?,
        slice: j.req_usize("slice")?,
        threads: j.req_usize("threads")?,
    })
}

fn features_json(features: &[f32]) -> Json {
    Json::Arr(features.iter().map(|&x| Json::Num(x as f64)).collect())
}

/// Optional `"trace"` hex field; absent (the v2 encoding) means
/// [`TraceId::NONE`].
fn trace_from_json(v: &Json) -> Result<TraceId> {
    match v.get("trace") {
        None => Ok(TraceId::NONE),
        Some(t) => {
            TraceId::parse(t.as_str().ok_or_else(|| anyhow!("\"trace\" is not a string"))?)
        }
    }
}

/// One coordinator-to-worker request.
#[derive(Clone, Debug, PartialEq)]
pub enum ClusterRequest {
    Ping,
    /// Connect-time negotiation: propose a wire for the data verbs.
    Hello { wire: WireFormat },
    /// Build this rank's weights. `shard: None` replicates the full
    /// weight set (feature partitioning); `Some((start, count))` holds
    /// only that contiguous row slice of every layer (v4 weight
    /// partitioning — never sent to pre-v4 peers, whose JSON parsers
    /// would silently ignore the field and build a full replica).
    Load {
        rank: usize,
        model: ModelSpec,
        spec: NativeSpec,
        prune: bool,
        shard: Option<(usize, usize)>,
    },
    /// Run all layers over one statically-partitioned feature shard.
    /// `trace` stitches the rank's spans into the caller's request
    /// trace; [`TraceId::NONE`] keeps the v2 encoding on both wires.
    Shard { start: usize, features: Vec<f32>, trace: TraceId },
    /// Open a pipelined scatter: `chunks` shard-chunk messages follow,
    /// covering `rows` feature rows from `start` in order. The trace
    /// context of the whole stream rides here (shard-begin is a JSON
    /// control line on both wires), not on each chunk.
    ShardBegin { start: usize, rows: usize, chunks: usize, trace: TraceId },
    /// One sub-panel of an open chunked scatter.
    ShardChunk { index: usize, start: usize, features: Vec<f32> },
    /// Weight-sharded mode (v4): run **one** layer of this rank's row
    /// shard over the full live feature panel `[rows, neurons]`. The
    /// rank answers with a [`ClusterReply::Partial`] panel
    /// `[rows, count]`. [`TraceId::NONE`] means untraced (the id is
    /// always on the frame; these kinds are only sent to v4 peers).
    Exchange { layer: usize, features: Vec<f32>, trace: TraceId },
    /// Telemetry pull (v5): the rank answers with its Prometheus
    /// exposition and recent flight-recorder events. Only sent to peers
    /// whose hello answered version ≥ 5.
    Metrics,
    /// Finish the current work and exit the worker process.
    Shutdown,
}

impl ClusterRequest {
    /// Short verb name (for diagnostics that must not debug-print a
    /// panel-sized payload).
    pub fn op(&self) -> &'static str {
        match self {
            ClusterRequest::Ping => "ping",
            ClusterRequest::Hello { .. } => "hello",
            ClusterRequest::Load { .. } => "load",
            ClusterRequest::Shard { .. } => "shard",
            ClusterRequest::ShardBegin { .. } => "shard-begin",
            ClusterRequest::ShardChunk { .. } => "shard-chunk",
            ClusterRequest::Exchange { .. } => "exchange",
            ClusterRequest::Metrics => "metrics",
            ClusterRequest::Shutdown => "shutdown",
        }
    }

    pub fn to_json(&self) -> Json {
        match self {
            ClusterRequest::Ping => Json::obj(vec![("op", Json::Str("ping".into()))]),
            ClusterRequest::Hello { wire } => Json::obj(vec![
                ("op", Json::Str("hello".into())),
                ("wire", Json::Str(wire.as_str().into())),
            ]),
            ClusterRequest::Load { rank, model, spec, prune, shard } => {
                let mut pairs = vec![
                    ("op", Json::Str("load".into())),
                    ("rank", Json::Int(*rank as i64)),
                    ("model", model.to_json()),
                    ("spec", spec_to_json(spec)),
                    ("prune", Json::Bool(*prune)),
                ];
                if let Some((start, count)) = shard {
                    pairs.push(("shard_start", Json::Int(*start as i64)));
                    pairs.push(("shard_count", Json::Int(*count as i64)));
                }
                Json::obj(pairs)
            }
            ClusterRequest::Shard { start, features, trace } => {
                let mut pairs = vec![
                    ("op", Json::Str("shard".into())),
                    ("start", Json::Int(*start as i64)),
                    ("features", features_json(features)),
                ];
                if trace.is_some() {
                    pairs.push(("trace", Json::Str(trace.to_hex())));
                }
                Json::obj(pairs)
            }
            ClusterRequest::ShardBegin { start, rows, chunks, trace } => {
                let mut pairs = vec![
                    ("op", Json::Str("shard-begin".into())),
                    ("start", Json::Int(*start as i64)),
                    ("rows", Json::Int(*rows as i64)),
                    ("chunks", Json::Int(*chunks as i64)),
                ];
                if trace.is_some() {
                    pairs.push(("trace", Json::Str(trace.to_hex())));
                }
                Json::obj(pairs)
            }
            ClusterRequest::ShardChunk { index, start, features } => Json::obj(vec![
                ("op", Json::Str("shard-chunk".into())),
                ("index", Json::Int(*index as i64)),
                ("start", Json::Int(*start as i64)),
                ("features", features_json(features)),
            ]),
            ClusterRequest::Exchange { layer, features, trace } => {
                let mut pairs = vec![
                    ("op", Json::Str("exchange".into())),
                    ("layer", Json::Int(*layer as i64)),
                    ("features", features_json(features)),
                ];
                if trace.is_some() {
                    pairs.push(("trace", Json::Str(trace.to_hex())));
                }
                Json::obj(pairs)
            }
            ClusterRequest::Metrics => Json::obj(vec![("op", Json::Str("metrics".into()))]),
            ClusterRequest::Shutdown => Json::obj(vec![("op", Json::Str("shutdown".into()))]),
        }
    }

    pub fn parse_line(line: &str) -> Result<ClusterRequest> {
        let v = Json::parse(line).context("cluster request is not valid JSON")?;
        match v.req_str("op")? {
            "ping" => Ok(ClusterRequest::Ping),
            "hello" => Ok(ClusterRequest::Hello { wire: WireFormat::parse(v.req_str("wire")?)? }),
            "load" => Ok(ClusterRequest::Load {
                rank: v.req_usize("rank")?,
                model: ModelSpec::from_json(v.req("model")?).context("\"model\"")?,
                spec: spec_from_json(v.req("spec")?).context("\"spec\"")?,
                prune: v
                    .req("prune")?
                    .as_bool()
                    .ok_or_else(|| anyhow!("\"prune\" is not a bool"))?,
                shard: match v.get("shard_start") {
                    None => None,
                    Some(s) => {
                        let start = s
                            .as_usize()
                            .ok_or_else(|| anyhow!("\"shard_start\" is not an unsigned int"))?;
                        Some((start, v.req_usize("shard_count")?))
                    }
                },
            }),
            "shard" => Ok(ClusterRequest::Shard {
                start: v.req_usize("start")?,
                features: parse_f32_array(v.req("features")?).context("\"features\"")?,
                trace: trace_from_json(&v)?,
            }),
            "shard-begin" => Ok(ClusterRequest::ShardBegin {
                start: v.req_usize("start")?,
                rows: v.req_usize("rows")?,
                chunks: v.req_usize("chunks")?,
                trace: trace_from_json(&v)?,
            }),
            "shard-chunk" => Ok(ClusterRequest::ShardChunk {
                index: v.req_usize("index")?,
                start: v.req_usize("start")?,
                features: parse_f32_array(v.req("features")?).context("\"features\"")?,
            }),
            "exchange" => Ok(ClusterRequest::Exchange {
                layer: v.req_usize("layer")?,
                features: parse_f32_array(v.req("features")?).context("\"features\"")?,
                trace: trace_from_json(&v)?,
            }),
            "metrics" => Ok(ClusterRequest::Metrics),
            "shutdown" => Ok(ClusterRequest::Shutdown),
            other => bail!("unknown cluster op {other:?}"),
        }
    }
}

/// What one rank computed for its shard: the gather payload plus the
/// per-layer trajectory the coordinator folds into the imbalance report.
#[derive(Clone, Debug, PartialEq)]
pub struct ShardResult {
    pub rank: usize,
    /// Global id of the shard's first feature (echoed for cover checks).
    pub start: usize,
    /// Features assigned to this shard (echoed for cover checks).
    pub count: usize,
    /// Surviving global feature ids, ascending.
    pub categories: Vec<usize>,
    /// Compacted final activations `[categories.len(), neurons]`.
    pub activations: Vec<f32>,
    /// Live features entering each layer.
    pub live_per_layer: Vec<usize>,
    /// Seconds per layer on this rank.
    pub layer_secs: Vec<f64>,
    pub edges_traversed: u64,
    /// Whole-shard wall seconds on the worker (for a chunked scatter:
    /// first chunk received to last chunk computed).
    pub secs: f64,
    /// Trace context echoed from the scatter ([`TraceId::NONE`] when
    /// the shard carried none — the v2 encoding on both wires).
    pub trace: TraceId,
    /// The rank's own spans for that trace (empty when untraced);
    /// re-recorded by the coordinator to stitch one end-to-end trace.
    pub spans: Vec<SpanRecord>,
}

impl ShardResult {
    pub fn busy_secs(&self) -> f64 {
        self.layer_secs.iter().sum()
    }

    fn to_json(&self) -> Json {
        let acts: Vec<f64> = self.activations.iter().map(|&x| x as f64).collect();
        let mut pairs = vec![
            ("ok", Json::Bool(true)),
            ("kind", Json::Str("result".into())),
            ("rank", Json::Int(self.rank as i64)),
            ("start", Json::Int(self.start as i64)),
            ("count", Json::Int(self.count as i64)),
            ("categories", Json::arr_usize(&self.categories)),
            ("activations", Json::arr_f64(&acts)),
            ("live_per_layer", Json::arr_usize(&self.live_per_layer)),
            ("layer_secs", Json::arr_f64(&self.layer_secs)),
            ("edges_traversed", Json::Int(self.edges_traversed as i64)),
            ("secs", Json::Num(self.secs)),
        ];
        if self.trace.is_some() {
            pairs.push(("trace", Json::Str(self.trace.to_hex())));
        }
        if !self.spans.is_empty() {
            pairs.push(("spans", spans_to_json(&self.spans)));
        }
        Json::obj(pairs)
    }
}

/// One worker-to-coordinator reply.
#[derive(Clone, Debug, PartialEq)]
pub enum ClusterReply {
    Pong { version: i64 },
    /// Negotiation echo: the worker's protocol version plus the wire it
    /// accepted for data frames.
    Hello { version: i64, wire: WireFormat },
    Loaded { rank: usize, neurons: usize, layers: usize },
    Result(Box<ShardResult>),
    /// Weight-sharded partial panel (v4): this rank's `[rows, count]`
    /// post-ReLU slice of one layer, answering an
    /// [`ClusterRequest::Exchange`]. `secs` is the rank's compute time
    /// for the layer (the coordinator's imbalance accounting).
    Partial { rank: usize, layer: usize, count: usize, secs: f64, values: Vec<f32> },
    /// Telemetry answer (v5): the rank's Prometheus exposition plus its
    /// recent flight-recorder events, shipped home so a coordinator
    /// post-mortem shows both sides of a severed connection.
    Metrics { text: String, events: Vec<FlightEvent> },
    /// Acknowledgement of a shutdown; the worker exits after sending it.
    Bye,
    Error { message: String },
}

impl ClusterReply {
    pub fn to_json(&self) -> Json {
        match self {
            ClusterReply::Pong { version } => Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("kind", Json::Str("pong".into())),
                ("version", Json::Int(*version)),
            ]),
            ClusterReply::Hello { version, wire } => Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("kind", Json::Str("hello".into())),
                ("version", Json::Int(*version)),
                ("wire", Json::Str(wire.as_str().into())),
            ]),
            ClusterReply::Loaded { rank, neurons, layers } => Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("kind", Json::Str("loaded".into())),
                ("rank", Json::Int(*rank as i64)),
                ("neurons", Json::Int(*neurons as i64)),
                ("layers", Json::Int(*layers as i64)),
            ]),
            ClusterReply::Result(r) => r.to_json(),
            ClusterReply::Partial { rank, layer, count, secs, values } => {
                let vals: Vec<f64> = values.iter().map(|&x| x as f64).collect();
                Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    ("kind", Json::Str("partial".into())),
                    ("rank", Json::Int(*rank as i64)),
                    ("layer", Json::Int(*layer as i64)),
                    ("count", Json::Int(*count as i64)),
                    ("secs", Json::Num(*secs)),
                    ("values", Json::arr_f64(&vals)),
                ])
            }
            ClusterReply::Metrics { text, events } => Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("kind", Json::Str("metrics".into())),
                ("text", Json::Str(text.clone())),
                ("events", flight::events_to_json(events)),
            ]),
            ClusterReply::Bye => Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("kind", Json::Str("bye".into())),
            ]),
            ClusterReply::Error { message } => Json::obj(vec![
                ("ok", Json::Bool(false)),
                ("kind", Json::Str("error".into())),
                ("error", Json::Str(message.clone())),
            ]),
        }
    }

    pub fn parse_line(line: &str) -> Result<ClusterReply> {
        let v = Json::parse(line).context("cluster reply is not valid JSON")?;
        match v.req_str("kind")? {
            "pong" => Ok(ClusterReply::Pong {
                version: v
                    .req("version")?
                    .as_i64()
                    .ok_or_else(|| anyhow!("\"version\" is not an int"))?,
            }),
            "hello" => Ok(ClusterReply::Hello {
                version: v
                    .req("version")?
                    .as_i64()
                    .ok_or_else(|| anyhow!("\"version\" is not an int"))?,
                wire: WireFormat::parse(v.req_str("wire")?)?,
            }),
            "loaded" => Ok(ClusterReply::Loaded {
                rank: v.req_usize("rank")?,
                neurons: v.req_usize("neurons")?,
                layers: v.req_usize("layers")?,
            }),
            "result" => Ok(ClusterReply::Result(Box::new(ShardResult {
                rank: v.req_usize("rank")?,
                start: v.req_usize("start")?,
                count: v.req_usize("count")?,
                categories: parse_usize_array(v.req("categories")?).context("\"categories\"")?,
                activations: parse_f32_array(v.req("activations")?).context("\"activations\"")?,
                live_per_layer: parse_usize_array(v.req("live_per_layer")?)
                    .context("\"live_per_layer\"")?,
                layer_secs: parse_f64_array(v.req("layer_secs")?).context("\"layer_secs\"")?,
                edges_traversed: v.req_usize("edges_traversed")? as u64,
                secs: v.req_f64("secs")?,
                trace: trace_from_json(&v)?,
                spans: match v.get("spans") {
                    Some(s) => spans_from_json(s).context("\"spans\"")?,
                    None => Vec::new(),
                },
            }))),
            "partial" => Ok(ClusterReply::Partial {
                rank: v.req_usize("rank")?,
                layer: v.req_usize("layer")?,
                count: v.req_usize("count")?,
                secs: v.req_f64("secs")?,
                values: parse_f32_array(v.req("values")?).context("\"values\"")?,
            }),
            "metrics" => Ok(ClusterReply::Metrics {
                text: v.req_str("text")?.to_string(),
                events: match v.get("events") {
                    Some(e) => flight::events_from_json(e).context("\"events\"")?,
                    None => Vec::new(),
                },
            }),
            "bye" => Ok(ClusterReply::Bye),
            "error" => Ok(ClusterReply::Error { message: v.req_str("error")?.to_string() }),
            other => bail!("unknown cluster reply kind {other:?}"),
        }
    }
}

fn parse_usize_array(j: &Json) -> Result<Vec<usize>> {
    let arr = j.as_arr().ok_or_else(|| anyhow!("expected an array of unsigned ints"))?;
    arr.iter()
        .map(|x| x.as_usize().ok_or_else(|| anyhow!("array element is not an unsigned int")))
        .collect()
}

fn parse_f64_array(j: &Json) -> Result<Vec<f64>> {
    let arr = j.as_arr().ok_or_else(|| anyhow!("expected an array of numbers"))?;
    arr.iter()
        .map(|x| {
            let f = x.as_f64().ok_or_else(|| anyhow!("array element is not a number"))?;
            if !f.is_finite() {
                bail!("array element is not finite");
            }
            Ok(f)
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Capped line reads
// ---------------------------------------------------------------------------

/// Bounds-checked line framing, shared with the serving front-end — the
/// implementation lives in [`crate::util::netio`]; this re-export keeps
/// the cluster-wire call sites and public path stable.
pub use crate::util::netio::read_line_capped;

// ---------------------------------------------------------------------------
// spdnn-clu1 binary frames
// ---------------------------------------------------------------------------

pub(crate) fn frame_header(kind: u8, payload_len: usize) -> Result<[u8; FRAME_HEADER_BYTES]> {
    let len = u32::try_from(payload_len).map_err(|_| {
        anyhow!("frame payload of {payload_len} bytes exceeds the u32 length prefix")
    })?;
    let mut h = [0u8; FRAME_HEADER_BYTES];
    h[..4].copy_from_slice(FRAME_MAGIC);
    h[4] = kind;
    h[5..9].copy_from_slice(&len.to_le_bytes());
    Ok(h)
}

pub(crate) fn read_frame(r: &mut impl BufRead, cap: usize) -> Result<(u8, Vec<u8>)> {
    let mut header = [0u8; FRAME_HEADER_BYTES];
    r.read_exact(&mut header).context("reading binary frame header")?;
    if &header[..4] != FRAME_MAGIC {
        bail!("bad frame magic {:?} (not an spdnn-clu1 frame)", &header[..4]);
    }
    let kind = header[4];
    let len = u32::from_le_bytes([header[5], header[6], header[7], header[8]]) as usize;
    if len > cap {
        bail!("binary frame of {len} bytes exceeds the {cap}-byte frame cap");
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)
        .with_context(|| format!("frame truncated (wanted {len} payload bytes)"))?;
    Ok((kind, payload))
}

/// Panel payload encodings inside shard / shard-chunk frames.
const ENC_DENSE: u8 = 0;
const ENC_UNIFORM: u8 = 1;

/// Detect the sparse-uniform case: every value is either +0.0 or one
/// shared bit pattern `v`. The challenge's input features are
/// thresholded binary images (exactly {0.0, 1.0}), so scatter panels
/// almost always qualify — and a bitmap plus one f32 is ~32× smaller
/// than dense. Bit-level comparison keeps the round trip exact (a -0.0
/// background falls back to dense).
pub(crate) fn uniform_value(features: &[f32]) -> Option<f32> {
    let mut v = 0u32;
    for &x in features {
        let b = x.to_bits();
        if b == 0 {
            continue;
        }
        if v == 0 {
            v = b;
        } else if v != b {
            return None;
        }
    }
    // All-zero panels encode as value +0.0 with an empty bitmap.
    Some(f32::from_bits(v))
}

pub(crate) fn panel_encoded_len(features: &[f32], uniform: Option<f32>) -> usize {
    1 + match uniform {
        Some(_) => 4 + features.len().div_ceil(8),
        None => features.len() * 4,
    }
}

/// Write `u8 enc` + the encoded panel, straight from the caller's
/// slice (dense data streams through a fixed staging buffer; the
/// uniform bitmap is 1/8th of the value count).
pub(crate) fn write_panel(
    w: &mut impl Write,
    features: &[f32],
    uniform: Option<f32>,
) -> Result<()> {
    match uniform {
        Some(v) => {
            let mut buf = Vec::with_capacity(1 + 4 + features.len().div_ceil(8));
            buf.push(ENC_UNIFORM);
            buf.extend_from_slice(&v.to_le_bytes());
            let mut byte = 0u8;
            for (i, &x) in features.iter().enumerate() {
                if x.to_bits() != 0 {
                    byte |= 1 << (i % 8);
                }
                if i % 8 == 7 {
                    buf.push(byte);
                    byte = 0;
                }
            }
            if features.len() % 8 != 0 {
                buf.push(byte);
            }
            w.write_all(&buf)?;
            Ok(())
        }
        None => {
            w.write_all(&[ENC_DENSE])?;
            write_f32s(w, features)
        }
    }
}

pub(crate) fn read_panel(c: &mut ByteCursor<'_>, n: usize) -> Result<Vec<f32>> {
    match c.u8()? {
        ENC_DENSE => c.f32s(n),
        ENC_UNIFORM => {
            let v = c.f32()?;
            let bitmap = c.bytes(n.div_ceil(8))?;
            let mut out = Vec::with_capacity(n);
            for i in 0..n {
                let set = (bitmap[i / 8] >> (i % 8)) & 1 == 1;
                out.push(if set { v } else { 0.0 });
            }
            Ok(out)
        }
        other => bail!("unknown panel encoding {other}"),
    }
}

/// Scatter one whole shard, writing straight from the caller's feature
/// slice — the steady-state path makes no panel-sized copy on either
/// wire. A [`TraceId::NONE`] keeps the exact v2 bytes; a real trace
/// selects the traced v3 encoding (only send it to v3 peers).
pub fn write_shard(
    w: &mut impl Write,
    wire: WireFormat,
    start: usize,
    features: &[f32],
    trace: TraceId,
) -> Result<()> {
    match wire {
        WireFormat::Json => {
            let mut pairs = vec![
                ("op", Json::Str("shard".into())),
                ("start", Json::Int(start as i64)),
                ("features", features_json(features)),
            ];
            if trace.is_some() {
                pairs.push(("trace", Json::Str(trace.to_hex())));
            }
            writeln!(w, "{}", Json::obj(pairs)).context("writing shard line")
        }
        WireFormat::Bin => {
            let uniform = uniform_value(features);
            let panel_len = 16 + panel_encoded_len(features, uniform);
            let mut meta = Vec::with_capacity(24);
            if trace.is_some() {
                w.write_all(&frame_header(FRAME_KIND_SHARD_TRACED, 8 + panel_len)?)?;
                put_u64(&mut meta, trace.0);
            } else {
                w.write_all(&frame_header(FRAME_KIND_SHARD, panel_len)?)?;
            }
            put_u64(&mut meta, start as u64);
            put_u64(&mut meta, features.len() as u64);
            w.write_all(&meta)?;
            write_panel(w, features, uniform).context("writing shard frame")
        }
    }
}

/// One sub-panel of a chunked scatter, written from the caller's slice.
pub fn write_shard_chunk(
    w: &mut impl Write,
    wire: WireFormat,
    index: usize,
    start: usize,
    features: &[f32],
) -> Result<()> {
    match wire {
        WireFormat::Json => {
            let obj = Json::obj(vec![
                ("op", Json::Str("shard-chunk".into())),
                ("index", Json::Int(index as i64)),
                ("start", Json::Int(start as i64)),
                ("features", features_json(features)),
            ]);
            writeln!(w, "{obj}").context("writing shard-chunk line")
        }
        WireFormat::Bin => {
            let uniform = uniform_value(features);
            let payload_len = 24 + panel_encoded_len(features, uniform);
            w.write_all(&frame_header(FRAME_KIND_SHARD_CHUNK, payload_len)?)?;
            let mut meta = Vec::with_capacity(24);
            put_u64(&mut meta, index as u64);
            put_u64(&mut meta, start as u64);
            put_u64(&mut meta, features.len() as u64);
            w.write_all(&meta)?;
            write_panel(w, features, uniform).context("writing shard-chunk frame")
        }
    }
}

/// Scatter one layer's full live panel for a weight-sharded pass,
/// written straight from the caller's slice (no panel-sized copy on
/// the binary wire). Unlike the shard kinds there is no untraced
/// legacy shape to preserve — these frames only ever reach v4 peers —
/// so the trace id is always on the frame, `0` meaning untraced.
pub fn write_exchange(
    w: &mut impl Write,
    wire: WireFormat,
    layer: usize,
    features: &[f32],
    trace: TraceId,
) -> Result<()> {
    match wire {
        WireFormat::Json => {
            let mut pairs = vec![
                ("op", Json::Str("exchange".into())),
                ("layer", Json::Int(layer as i64)),
                ("features", features_json(features)),
            ];
            if trace.is_some() {
                pairs.push(("trace", Json::Str(trace.to_hex())));
            }
            writeln!(w, "{}", Json::obj(pairs)).context("writing exchange line")
        }
        WireFormat::Bin => {
            let uniform = uniform_value(features);
            let payload_len = 24 + panel_encoded_len(features, uniform);
            w.write_all(&frame_header(FRAME_KIND_EXCHANGE, payload_len)?)?;
            let mut meta = Vec::with_capacity(24);
            put_u64(&mut meta, trace.0);
            put_u64(&mut meta, layer as u64);
            put_u64(&mut meta, features.len() as u64);
            w.write_all(&meta)?;
            write_panel(w, features, uniform).context("writing exchange frame")
        }
    }
}

fn write_partial_frame(
    w: &mut impl Write,
    rank: usize,
    layer: usize,
    count: usize,
    secs: f64,
    values: &[f32],
) -> Result<()> {
    let uniform = uniform_value(values);
    let payload_len = 40 + panel_encoded_len(values, uniform);
    w.write_all(&frame_header(FRAME_KIND_PARTIAL, payload_len)?)?;
    let mut meta = Vec::with_capacity(40);
    put_u64(&mut meta, rank as u64);
    put_u64(&mut meta, layer as u64);
    put_u64(&mut meta, count as u64);
    put_u64(&mut meta, values.len() as u64);
    put_f64(&mut meta, secs);
    w.write_all(&meta)?;
    write_panel(w, values, uniform).context("writing partial frame")
}

fn write_result_frame(w: &mut impl Write, r: &ShardResult) -> Result<()> {
    let body_len = 8 * 8
        + 8
        + r.categories.len() * 8
        + r.activations.len() * 4
        + r.live_per_layer.len() * 8
        + r.layer_secs.len() * 8;
    let mut buf = Vec::new();
    if r.trace.is_some() || !r.spans.is_empty() {
        // Traced v3 result: trace id plus a length-prefixed span blob
        // (JSON — spans are tiny and low-rate) ahead of the v2 body.
        let blob = spans_to_json(&r.spans).to_string().into_bytes();
        w.write_all(&frame_header(FRAME_KIND_RESULT_TRACED, 16 + blob.len() + body_len)?)?;
        put_u64(&mut buf, r.trace.0);
        put_u64(&mut buf, blob.len() as u64);
        buf.extend_from_slice(&blob);
    } else {
        w.write_all(&frame_header(FRAME_KIND_RESULT, body_len)?)?;
    }
    for m in [
        r.rank as u64,
        r.start as u64,
        r.count as u64,
        r.categories.len() as u64,
        r.activations.len() as u64,
        r.live_per_layer.len() as u64,
        r.layer_secs.len() as u64,
        r.edges_traversed,
    ] {
        put_u64(&mut buf, m);
    }
    put_f64(&mut buf, r.secs);
    for &c in &r.categories {
        put_u64(&mut buf, c as u64);
    }
    w.write_all(&buf)?;
    write_f32s(w, &r.activations)?;
    buf.clear();
    for &v in &r.live_per_layer {
        put_u64(&mut buf, v as u64);
    }
    for &s in &r.layer_secs {
        put_f64(&mut buf, s);
    }
    w.write_all(&buf).context("writing result frame")
}

fn usize_of(x: u64, what: &str) -> Result<usize> {
    usize::try_from(x).map_err(|_| anyhow!("{what} {x} does not fit in usize"))
}

fn parse_request_frame(kind: u8, payload: &[u8]) -> Result<ClusterRequest> {
    let mut c = ByteCursor::new(payload);
    match kind {
        FRAME_KIND_SHARD | FRAME_KIND_SHARD_TRACED => {
            let trace = if kind == FRAME_KIND_SHARD_TRACED {
                TraceId(c.u64().context("shard trace id")?)
            } else {
                TraceId::NONE
            };
            let start = usize_of(c.u64()?, "shard start")?;
            let n = usize_of(c.u64()?, "shard value count")?;
            let features = read_panel(&mut c, n).context("shard frame features")?;
            c.finish().context("shard frame")?;
            Ok(ClusterRequest::Shard { start, features, trace })
        }
        FRAME_KIND_SHARD_CHUNK => {
            let index = usize_of(c.u64()?, "chunk index")?;
            let start = usize_of(c.u64()?, "chunk start")?;
            let n = usize_of(c.u64()?, "chunk value count")?;
            let features = read_panel(&mut c, n).context("shard-chunk frame features")?;
            c.finish().context("shard-chunk frame")?;
            Ok(ClusterRequest::ShardChunk { index, start, features })
        }
        FRAME_KIND_EXCHANGE => {
            let trace = TraceId(c.u64().context("exchange trace id")?);
            let layer = usize_of(c.u64()?, "exchange layer")?;
            let n = usize_of(c.u64()?, "exchange value count")?;
            let features = read_panel(&mut c, n).context("exchange frame features")?;
            c.finish().context("exchange frame")?;
            Ok(ClusterRequest::Exchange { layer, features, trace })
        }
        FRAME_KIND_RESULT | FRAME_KIND_RESULT_TRACED | FRAME_KIND_PARTIAL => {
            bail!("result frame is a reply, not a request")
        }
        other => bail!("unknown request frame kind {other}"),
    }
}

fn parse_reply_frame(kind: u8, payload: &[u8]) -> Result<ClusterReply> {
    if kind == FRAME_KIND_PARTIAL {
        let mut c = ByteCursor::new(payload);
        let rank = usize_of(c.u64()?, "partial rank")?;
        let layer = usize_of(c.u64()?, "partial layer")?;
        let count = usize_of(c.u64()?, "partial count")?;
        let n = usize_of(c.u64()?, "partial value count")?;
        let secs = c.f64()?;
        let values = read_panel(&mut c, n).context("partial frame values")?;
        c.finish().context("partial frame")?;
        return Ok(ClusterReply::Partial { rank, layer, count, secs, values });
    }
    if kind != FRAME_KIND_RESULT && kind != FRAME_KIND_RESULT_TRACED {
        bail!("unknown reply frame kind {kind}");
    }
    let mut c = ByteCursor::new(payload);
    let (trace, spans) = if kind == FRAME_KIND_RESULT_TRACED {
        let trace = TraceId(c.u64().context("result trace id")?);
        let nspan = usize_of(c.u64()?, "result span blob length")?;
        let blob = c.bytes(nspan).context("result frame span blob")?;
        let doc = Json::parse(std::str::from_utf8(blob).context("result span blob is not UTF-8")?)
            .context("result span blob")?;
        (trace, spans_from_json(&doc).context("result frame spans")?)
    } else {
        (TraceId::NONE, Vec::new())
    };
    let rank = usize_of(c.u64()?, "result rank")?;
    let start = usize_of(c.u64()?, "result start")?;
    let count = usize_of(c.u64()?, "result count")?;
    let ncats = usize_of(c.u64()?, "result category count")?;
    let nacts = usize_of(c.u64()?, "result activation count")?;
    let nlive = usize_of(c.u64()?, "result live count")?;
    let nsecs = usize_of(c.u64()?, "result layer-secs count")?;
    let edges_traversed = c.u64()?;
    let secs = c.f64()?;
    let categories = c
        .u64s(ncats)
        .context("result frame categories")?
        .into_iter()
        .map(|x| usize_of(x, "category"))
        .collect::<Result<Vec<usize>>>()?;
    let activations = c.f32s(nacts).context("result frame activations")?;
    let live_per_layer = c
        .u64s(nlive)
        .context("result frame live_per_layer")?
        .into_iter()
        .map(|x| usize_of(x, "live count"))
        .collect::<Result<Vec<usize>>>()?;
    let layer_secs = c.f64s(nsecs).context("result frame layer_secs")?;
    c.finish().context("result frame")?;
    Ok(ClusterReply::Result(Box::new(ShardResult {
        rank,
        start,
        count,
        categories,
        activations,
        live_per_layer,
        layer_secs,
        edges_traversed,
        secs,
        trace,
        spans,
    })))
}

/// Serialize one request on the negotiated wire. Data verbs become
/// binary frames on `Bin`; everything else is a JSON line on both.
pub fn write_request(w: &mut impl Write, req: &ClusterRequest, wire: WireFormat) -> Result<()> {
    match (wire, req) {
        (WireFormat::Bin, ClusterRequest::Shard { start, features, trace }) => {
            write_shard(w, wire, *start, features, *trace)
        }
        (WireFormat::Bin, ClusterRequest::ShardChunk { index, start, features }) => {
            write_shard_chunk(w, wire, *index, *start, features)
        }
        (WireFormat::Bin, ClusterRequest::Exchange { layer, features, trace }) => {
            write_exchange(w, wire, *layer, features, *trace)
        }
        _ => writeln!(w, "{}", req.to_json()).context("writing cluster request"),
    }
}

/// Serialize one reply on the negotiated wire (`result` and `partial`
/// are the binary-capable replies).
pub fn write_reply(w: &mut impl Write, reply: &ClusterReply, wire: WireFormat) -> Result<()> {
    match (wire, reply) {
        (WireFormat::Bin, ClusterReply::Result(r)) => write_result_frame(w, r),
        (WireFormat::Bin, ClusterReply::Partial { rank, layer, count, secs, values }) => {
            write_partial_frame(w, *rank, *layer, *count, *secs, values)
        }
        _ => writeln!(w, "{}", reply.to_json()).context("writing cluster reply"),
    }
}

/// Peek the first byte of the next message, consuming blank separators.
fn peek_first_byte(r: &mut impl BufRead) -> Result<Option<u8>> {
    loop {
        let b = {
            let buf = r.fill_buf().context("reading from cluster peer")?;
            if buf.is_empty() {
                return Ok(None);
            }
            buf[0]
        };
        if b == b'\n' || b == b'\r' {
            r.consume(1);
            continue;
        }
        return Ok(Some(b));
    }
}

/// What one read off the request stream produced. The split matters
/// for connection lifetime: an [`ReadOutcome::Invalid`] message was
/// fully consumed (newline-terminated line, or a complete frame), so
/// the stream is still in sync and the server can reply with an error
/// and keep serving — whereas a framing failure (cap exceeded, bad
/// magic, truncated frame: the `Err` of [`read_request`]) leaves the
/// stream unrecoverable and the connection must drop.
pub enum ReadOutcome {
    /// Clean EOF.
    Eof,
    /// A well-formed request plus the wire it arrived in.
    Msg(ClusterRequest, WireFormat),
    /// A fully-consumed but invalid message (unknown op, missing or
    /// malformed field): reply with an error and keep reading.
    Invalid(anyhow::Error, WireFormat),
}

/// Read one request off the stream — JSON line or binary frame, told
/// apart by the first byte — enforcing `cap` on either encoding.
/// Replies go back in the wire the request arrived in. `Err` means the
/// stream itself broke (see [`ReadOutcome`]).
pub fn read_request(r: &mut impl BufRead, cap: usize) -> Result<ReadOutcome> {
    let first = match peek_first_byte(r)? {
        None => return Ok(ReadOutcome::Eof),
        Some(b) => b,
    };
    if first == FRAME_MAGIC[0] {
        let (kind, payload) = read_frame(r, cap)?;
        Ok(match parse_request_frame(kind, &payload) {
            Ok(req) => ReadOutcome::Msg(req, WireFormat::Bin),
            Err(e) => ReadOutcome::Invalid(e, WireFormat::Bin),
        })
    } else {
        let mut line = String::new();
        if read_line_capped(r, &mut line, cap)? == 0 {
            return Ok(ReadOutcome::Eof);
        }
        Ok(match ClusterRequest::parse_line(line.trim()) {
            Ok(req) => ReadOutcome::Msg(req, WireFormat::Json),
            Err(e) => ReadOutcome::Invalid(e, WireFormat::Json),
        })
    }
}

/// Read one reply off the stream (see [`read_request`]).
pub fn read_reply(r: &mut impl BufRead, cap: usize) -> Result<Option<ClusterReply>> {
    let first = match peek_first_byte(r)? {
        None => return Ok(None),
        Some(b) => b,
    };
    if first == FRAME_MAGIC[0] {
        let (kind, payload) = read_frame(r, cap)?;
        Ok(Some(parse_reply_frame(kind, &payload)?))
    } else {
        let mut line = String::new();
        if read_line_capped(r, &mut line, cap)? == 0 {
            return Ok(None);
        }
        Ok(Some(ClusterReply::parse_line(line.trim())?))
    }
}

// ---------------------------------------------------------------------------
// Rank-0 client
// ---------------------------------------------------------------------------

/// Byte-counting stream halves: the scatter/gather byte accounting the
/// bench ablations report comes straight off these counters.
struct CountingReader {
    inner: TcpStream,
    bytes: u64,
}

impl Read for CountingReader {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        let n = self.inner.read(buf)?;
        self.bytes += n as u64;
        Ok(n)
    }
}

struct CountingWriter {
    inner: TcpStream,
    bytes: u64,
}

impl Write for CountingWriter {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        let n = self.inner.write(buf)?;
        self.bytes += n as u64;
        Ok(n)
    }

    fn flush(&mut self) -> std::io::Result<()> {
        self.inner.flush()
    }
}

/// Blocking wire client held by rank 0, one per worker rank. Carries
/// the negotiated [`WireFormat`] and the model-derived frame cap.
pub struct ClusterClient {
    reader: BufReader<CountingReader>,
    writer: BufWriter<CountingWriter>,
    wire: WireFormat,
    /// Reply frame cap; starts at the control cap, widened by
    /// [`ClusterClient::set_model`] after a successful load.
    cap: usize,
    /// The protocol version the worker's hello answered; gates the
    /// traced v3 encodings ([`ClusterClient::supports_trace`]).
    peer_version: i64,
    /// The rank's address — names the corpse in timeout flight events.
    addr: SocketAddr,
    /// Socket read/write deadline set by [`ClusterClient::set_io_timeout`].
    io_timeout: Option<Duration>,
}

impl ClusterClient {
    /// Connect and negotiate `wire` for the data verbs. The worker
    /// normally echoes the proposed wire; a peer that answers `json` to
    /// a `bin` proposal (a v1-era binary whose only data encoding is
    /// JSON lines) is **downgraded to** rather than rejected — every
    /// coordinator speaks JSON, so no frames are lost, just bytes.
    /// Anything else — a version this coordinator does not know, or a
    /// peer claiming an encoding we did not propose and cannot assume —
    /// fails here with a clear diagnostic instead of a parse error deep
    /// inside load/shard.
    pub fn connect(addr: SocketAddr, wire: WireFormat) -> Result<ClusterClient> {
        let stream =
            TcpStream::connect(addr).with_context(|| format!("connecting to rank at {addr}"))?;
        stream.set_nodelay(true).ok();
        let wstream = stream.try_clone().context("cloning cluster stream")?;
        let mut client = ClusterClient {
            reader: BufReader::new(CountingReader { inner: stream, bytes: 0 }),
            writer: BufWriter::new(CountingWriter { inner: wstream, bytes: 0 }),
            wire,
            cap: CONTROL_FRAME_CAP,
            peer_version: CLUSTER_PROTOCOL_VERSION,
            addr,
            io_timeout: None,
        };
        match client.call(&ClusterRequest::Hello { wire })? {
            ClusterReply::Hello { version, wire: got } => {
                if !(1..=CLUSTER_PROTOCOL_VERSION).contains(&version) {
                    flight::record(flight::HELLO_REFUSED, || {
                        format!("worker at {addr} speaks unsupported protocol v{version}")
                    });
                    bail!(
                        "worker speaks cluster protocol v{version}, this coordinator \
                         speaks v{CLUSTER_PROTOCOL_VERSION} (mixed spdnn binaries?)"
                    );
                }
                client.peer_version = version;
                if got == wire && version == CLUSTER_PROTOCOL_VERSION {
                    return Ok(client);
                }
                // Graceful downgrade: a peer that answers `json` — a
                // v1-era binary whose only data encoding is JSON lines,
                // or a newer build refusing bin — settles the connection
                // on json; every coordinator speaks it, so no frames
                // are lost, just bytes. The reverse (echoing bin to a
                // json proposal, or a v1 peer claiming bin) would put
                // frames on a wire this caller did not propose, so it
                // stays an error.
                if got == WireFormat::Json {
                    if wire == WireFormat::Bin {
                        flight::record(flight::HELLO_DOWNGRADE, || {
                            format!("worker at {addr} (v{version}): bin wire downgraded to json")
                        });
                        crate::log_warn!(
                            "worker at {addr} speaks protocol v{version} with json-only \
                             data frames; downgrading this connection from bin to json"
                        );
                    }
                    client.wire = WireFormat::Json;
                    return Ok(client);
                }
                if got == wire && version >= CLUSTER_PROTOCOL_BIN_COMPAT {
                    // The untraced v2 frames are a byte-identical
                    // subset, and the newer encodings — traced kinds
                    // 5/6, exchange kinds 7/8 — are gated on this
                    // version, so an older peer stays fully compatible
                    // on either wire; it just cannot contribute trace
                    // spans (pre-v3) or hold a weight shard (pre-v4).
                    flight::record(flight::HELLO_DOWNGRADE, || {
                        format!(
                            "worker at {addr} answered v{version}; \
                             v{CLUSTER_PROTOCOL_VERSION} features disabled"
                        )
                    });
                    crate::log_warn!(
                        "worker at {addr} speaks protocol v{version}; newer protocol \
                         features are disabled on this connection (coordinator is v{})",
                        CLUSTER_PROTOCOL_VERSION
                    );
                    return Ok(client);
                }
                flight::record(flight::HELLO_REFUSED, || {
                    format!("worker at {addr} (v{version}) offered wire {got}, wanted {wire}")
                });
                if version != CLUSTER_PROTOCOL_VERSION {
                    // An old peer claiming a non-json wire: the version
                    // skew is the real problem — its binary framing
                    // cannot be assumed compatible.
                    bail!(
                        "worker speaks cluster protocol v{version} but offered the {got} \
                         wire; only json data frames are assumed across versions \
                         (mixed spdnn binaries?)"
                    );
                }
                bail!("worker negotiated wire {got}, wanted {wire}")
            }
            ClusterReply::Error { message } => {
                flight::record(flight::HELLO_REFUSED, || {
                    format!("worker at {addr} rejected the handshake: {message}")
                });
                bail!("handshake rejected: {message}")
            }
            other => bail!("unexpected handshake reply {other:?}"),
        }
    }

    /// Widen the reply frame cap to the negotiated model (call after a
    /// successful `load`).
    pub fn set_model(&mut self, neurons: usize) {
        self.cap = data_frame_cap(neurons);
    }

    /// Liveness probe: one ping round-trip (any protocol version).
    pub fn ping(&mut self) -> Result<()> {
        match self.call(&ClusterRequest::Ping)? {
            ClusterReply::Pong { .. } => Ok(()),
            other => bail!("unexpected ping reply {other:?}"),
        }
    }

    pub fn wire(&self) -> WireFormat {
        self.wire
    }

    /// Whether the negotiated peer understands the traced v3 encodings.
    /// When false, [`ClusterClient::send_shard`] silently drops the
    /// trace context instead of sending frames the peer would reject.
    pub fn supports_trace(&self) -> bool {
        self.peer_version >= CLUSTER_PROTOCOL_TRACE_MIN
    }

    /// Whether the negotiated peer understands weight-sharded loads and
    /// the v4 exchange/partial encodings. Unlike traces there is no
    /// silent degradation: an old worker's JSON parser would ignore the
    /// shard range and build a full replica, so the coordinator must
    /// refuse weights mode against a peer where this is false.
    pub fn supports_weights(&self) -> bool {
        self.peer_version >= CLUSTER_PROTOCOL_WEIGHTS_MIN
    }

    /// Whether the negotiated peer answers the `metrics` telemetry
    /// pull. A pre-v5 peer keeps serving shards; the federated document
    /// just reports it down (`spdnn_fleet_rank_up 0`).
    pub fn supports_metrics(&self) -> bool {
        self.peer_version >= CLUSTER_PROTOCOL_METRICS_MIN
    }

    /// Set (or clear) a socket read/write deadline for every subsequent
    /// collective on this connection. A rank that stops making I/O
    /// progress for this long fails the in-flight call — surfaced as a
    /// [`flight::RANK_DEATH`] event naming the rank — instead of
    /// hanging the coordinator forever on a wedged-but-connected peer.
    pub fn set_io_timeout(&mut self, timeout: Option<Duration>) -> Result<()> {
        self.reader
            .get_ref()
            .inner
            .set_read_timeout(timeout)
            .context("setting cluster read timeout")?;
        self.writer
            .get_ref()
            .inner
            .set_write_timeout(timeout)
            .context("setting cluster write timeout")?;
        self.io_timeout = timeout;
        Ok(())
    }

    /// Run one wire interaction; if it fails on an I/O deadline, record
    /// the rank-death flight event before handing the error up (the
    /// caller's rank-failure path then lame-ducks as for a dead peer).
    fn guard<T>(&mut self, f: impl FnOnce(&mut Self) -> Result<T>) -> Result<T> {
        match f(self) {
            Ok(v) => Ok(v),
            Err(e) => {
                if error_is_timeout(&e) {
                    let (addr, timeout) = (self.addr, self.io_timeout);
                    flight::record(flight::RANK_DEATH, || {
                        format!(
                            "rank at {addr} made no socket progress within {:.0}ms; \
                             treating it as dead",
                            timeout.unwrap_or_default().as_secs_f64() * 1e3
                        )
                    });
                }
                Err(e)
            }
        }
    }

    /// Bytes written to the socket so far (flushed requests only).
    pub fn bytes_sent(&self) -> u64 {
        self.writer.get_ref().bytes
    }

    /// Bytes read off the socket so far.
    pub fn bytes_received(&self) -> u64 {
        self.reader.get_ref().bytes
    }

    /// Send one request and block for its reply.
    pub fn call(&mut self, req: &ClusterRequest) -> Result<ClusterReply> {
        self.guard(|c| {
            write_request(&mut c.writer, req, c.wire)?;
            c.writer.flush().context("flushing cluster request")?;
            c.read_one_reply()
        })
    }

    /// Scatter one shard straight from the caller's feature slice —
    /// whole (`chunk_rows: None`), or as a pipelined stream of
    /// `chunk_rows`-row sub-panels the worker starts computing on while
    /// later chunks are still in flight (the §III.B overlap analog).
    pub fn send_shard(
        &mut self,
        start: usize,
        features: &[f32],
        neurons: usize,
        chunk_rows: Option<usize>,
        trace: TraceId,
    ) -> Result<ClusterReply> {
        let n = neurons.max(1);
        // Never put traced encodings on a connection whose peer did not
        // negotiate them; the shard still runs, just untraced.
        let trace = if self.supports_trace() { trace } else { TraceId::NONE };
        self.guard(|c| {
            match chunk_rows {
                None => {
                    write_shard(&mut c.writer, c.wire, start, features, trace)?;
                    c.writer.flush().context("flushing shard")?;
                }
                Some(rows_per_chunk) => {
                    let rows_per_chunk = rows_per_chunk.max(1);
                    let rows = features.len() / n;
                    let chunks = rows.div_ceil(rows_per_chunk);
                    let begin = ClusterRequest::ShardBegin { start, rows, chunks, trace };
                    write_request(&mut c.writer, &begin, c.wire)?;
                    c.writer.flush().context("flushing shard-begin")?;
                    for (i, chunk) in features.chunks(rows_per_chunk * n).enumerate() {
                        write_shard_chunk(
                            &mut c.writer,
                            c.wire,
                            i,
                            start + i * rows_per_chunk,
                            chunk,
                        )?;
                        // Eager flush: the worker overlaps compute on this
                        // chunk with the transfer of the next one.
                        c.writer.flush().context("flushing shard chunk")?;
                    }
                }
            }
            c.read_one_reply()
        })
    }

    /// Weight-sharded mode: scatter one layer's full live panel
    /// straight from the caller's slice and block for the rank's
    /// [`ClusterReply::Partial`]. Only valid on peers where
    /// [`ClusterClient::supports_weights`] holds.
    pub fn exchange(
        &mut self,
        layer: usize,
        features: &[f32],
        trace: TraceId,
    ) -> Result<ClusterReply> {
        self.guard(|c| {
            write_exchange(&mut c.writer, c.wire, layer, features, trace)?;
            c.writer.flush().context("flushing exchange")?;
            c.read_one_reply()
        })
    }

    fn read_one_reply(&mut self) -> Result<ClusterReply> {
        match read_reply(&mut self.reader, self.cap).context("reading cluster reply")? {
            Some(reply) => Ok(reply),
            None => bail!("worker closed the connection"),
        }
    }
}

/// Whether an error chain bottoms out in a socket deadline expiry.
/// `WouldBlock` is included: reads against a timeout-configured stream
/// report it on some platforms.
fn error_is_timeout(e: &anyhow::Error) -> bool {
    e.chain().any(|cause| match cause.downcast_ref::<std::io::Error>() {
        Some(io) => matches!(
            io.kind(),
            std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
        ),
        None => false,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Xoshiro256;
    use crate::util::proptest::{self, Runner};

    fn model() -> ModelSpec {
        ModelSpec {
            neurons: 64,
            layers: 5,
            k: 4,
            topology: "butterfly".into(),
            seed: 7,
            bias: -0.3,
        }
    }

    fn spec() -> NativeSpec {
        NativeSpec { engine: EngineKind::Sliced, minibatch: 12, slice: 32, threads: 2 }
    }

    fn sample_result() -> ShardResult {
        ShardResult {
            rank: 2,
            start: 8,
            count: 4,
            categories: vec![9, 11],
            activations: vec![0.5, 0.0, 1.25, 32.0],
            live_per_layer: vec![4, 3, 2, 2, 2],
            layer_secs: vec![0.25, 0.125, 0.0625, 0.5, 0.125],
            edges_traversed: 1234,
            secs: 1.5,
            trace: TraceId::NONE,
            spans: vec![],
        }
    }

    fn traced_result() -> ShardResult {
        ShardResult {
            trace: TraceId(0xDEAD_BEEF),
            spans: vec![
                SpanRecord {
                    name: "compute".into(),
                    ts_us: 1_000_000,
                    dur_us: 1500,
                    trace: TraceId(0xDEAD_BEEF),
                    lane: 3,
                    tid: 0,
                    args: vec![("rank".into(), "2".into())],
                },
                SpanRecord {
                    name: "layer".into(),
                    ts_us: 1_000_100,
                    dur_us: 200,
                    trace: TraceId(0xDEAD_BEEF),
                    lane: 3,
                    tid: 0,
                    args: vec![],
                },
            ],
            ..sample_result()
        }
    }

    fn roundtrip_request(req: ClusterRequest) {
        let line = req.to_json().to_string();
        assert_eq!(ClusterRequest::parse_line(&line).unwrap(), req, "line: {line}");
    }

    fn roundtrip_reply(reply: ClusterReply) {
        let line = reply.to_json().to_string();
        assert_eq!(ClusterReply::parse_line(&line).unwrap(), reply, "line: {line}");
    }

    /// Unwrap one well-formed request off a buffer.
    fn read_msg(r: &mut &[u8], cap: usize) -> (ClusterRequest, WireFormat) {
        match read_request(r, cap).unwrap() {
            ReadOutcome::Msg(req, wire) => (req, wire),
            ReadOutcome::Eof => panic!("unexpected EOF"),
            ReadOutcome::Invalid(e, _) => panic!("invalid message: {e:#}"),
        }
    }

    /// Round-trip through the full framed writer/reader pair.
    fn roundtrip_request_wire(req: ClusterRequest, wire: WireFormat) {
        let mut buf = Vec::new();
        write_request(&mut buf, &req, wire).unwrap();
        let mut r = &buf[..];
        let (back, _) = read_msg(&mut r, 1 << 24);
        assert_eq!(back, req, "wire: {wire}");
        assert!(
            matches!(read_request(&mut r, 1 << 24).unwrap(), ReadOutcome::Eof),
            "stream fully consumed"
        );
    }

    fn roundtrip_reply_wire(reply: ClusterReply, wire: WireFormat) {
        let mut buf = Vec::new();
        write_reply(&mut buf, &reply, wire).unwrap();
        let mut r = &buf[..];
        let back = read_reply(&mut r, 1 << 24).unwrap().unwrap();
        assert_eq!(back, reply, "wire: {wire}");
    }

    #[test]
    fn request_roundtrips() {
        roundtrip_request(ClusterRequest::Ping);
        roundtrip_request(ClusterRequest::Hello { wire: WireFormat::Bin });
        roundtrip_request(ClusterRequest::Hello { wire: WireFormat::Json });
        roundtrip_request(ClusterRequest::Load {
            rank: 3,
            model: model(),
            spec: spec(),
            prune: true,
            shard: None,
        });
        roundtrip_request(ClusterRequest::Load {
            rank: 1,
            model: model(),
            spec: spec(),
            prune: false,
            shard: Some((22, 21)),
        });
        roundtrip_request(ClusterRequest::Shard {
            start: 12,
            features: vec![0.0, 1.5, 0.25, 3.125],
            trace: TraceId::NONE,
        });
        roundtrip_request(ClusterRequest::Shard {
            start: 12,
            features: vec![1.0, 0.0],
            trace: TraceId(0xAB),
        });
        roundtrip_request(ClusterRequest::ShardBegin {
            start: 4,
            rows: 12,
            chunks: 3,
            trace: TraceId::NONE,
        });
        roundtrip_request(ClusterRequest::ShardBegin {
            start: 4,
            rows: 12,
            chunks: 3,
            trace: TraceId::generate(),
        });
        roundtrip_request(ClusterRequest::ShardChunk {
            index: 1,
            start: 8,
            features: vec![2.5, -0.75],
        });
        roundtrip_request(ClusterRequest::Exchange {
            layer: 3,
            features: vec![0.0, 1.25, 0.5],
            trace: TraceId::NONE,
        });
        roundtrip_request(ClusterRequest::Exchange {
            layer: 0,
            features: vec![1.0, 0.0],
            trace: TraceId(0xC0FFEE),
        });
        roundtrip_request(ClusterRequest::Metrics);
        roundtrip_request(ClusterRequest::Shutdown);
    }

    #[test]
    fn reply_roundtrips() {
        roundtrip_reply(ClusterReply::Pong { version: CLUSTER_PROTOCOL_VERSION });
        roundtrip_reply(ClusterReply::Hello {
            version: CLUSTER_PROTOCOL_VERSION,
            wire: WireFormat::Bin,
        });
        roundtrip_reply(ClusterReply::Loaded { rank: 1, neurons: 64, layers: 5 });
        roundtrip_reply(ClusterReply::Result(Box::new(sample_result())));
        roundtrip_reply(ClusterReply::Result(Box::new(traced_result())));
        roundtrip_reply(ClusterReply::Partial {
            rank: 1,
            layer: 4,
            count: 21,
            secs: 0.125,
            values: vec![0.0, 32.0, 0.5],
        });
        roundtrip_reply(ClusterReply::Metrics { text: String::new(), events: vec![] });
        roundtrip_reply(ClusterReply::Metrics {
            text: "# HELP spdnn_rank_shards_total shards\n\
                   # TYPE spdnn_rank_shards_total counter\n\
                   spdnn_rank_shards_total 3\n"
                .into(),
            events: vec![FlightEvent {
                seq: 7,
                ts_us: 1_000_000,
                kind: flight::FRAME_ERROR.into(),
                detail: "bad magic".into(),
            }],
        });
        roundtrip_reply(ClusterReply::Bye);
        roundtrip_reply(ClusterReply::Error { message: "boom".into() });
    }

    #[test]
    fn every_request_roundtrips_on_both_wires() {
        for wire in [WireFormat::Json, WireFormat::Bin] {
            roundtrip_request_wire(ClusterRequest::Ping, wire);
            roundtrip_request_wire(ClusterRequest::Hello { wire }, wire);
            roundtrip_request_wire(
                ClusterRequest::Load {
                    rank: 0,
                    model: model(),
                    spec: spec(),
                    prune: false,
                    shard: None,
                },
                wire,
            );
            roundtrip_request_wire(
                ClusterRequest::Load {
                    rank: 2,
                    model: model(),
                    spec: spec(),
                    prune: true,
                    shard: Some((43, 21)),
                },
                wire,
            );
            roundtrip_request_wire(
                ClusterRequest::Shard {
                    start: 3,
                    features: vec![0.1, 1.0 / 3.0, 31.5],
                    trace: TraceId::NONE,
                },
                wire,
            );
            roundtrip_request_wire(
                ClusterRequest::Shard {
                    start: 3,
                    features: vec![0.1, 1.0 / 3.0, 31.5],
                    trace: TraceId(0x0123_4567_89AB_CDEF),
                },
                wire,
            );
            roundtrip_request_wire(
                ClusterRequest::ShardBegin { start: 0, rows: 7, chunks: 2, trace: TraceId::NONE },
                wire,
            );
            roundtrip_request_wire(
                ClusterRequest::ShardBegin { start: 0, rows: 7, chunks: 2, trace: TraceId(9) },
                wire,
            );
            roundtrip_request_wire(
                ClusterRequest::ShardChunk { index: 0, start: 0, features: vec![] },
                wire,
            );
            roundtrip_request_wire(
                ClusterRequest::Exchange {
                    layer: 2,
                    features: vec![0.0, 1.0, 1.0, 0.0],
                    trace: TraceId::NONE,
                },
                wire,
            );
            roundtrip_request_wire(
                ClusterRequest::Exchange { layer: 2, features: vec![0.5, 0.25], trace: TraceId(7) },
                wire,
            );
            roundtrip_request_wire(ClusterRequest::Metrics, wire);
            roundtrip_request_wire(ClusterRequest::Shutdown, wire);
            roundtrip_reply_wire(ClusterReply::Result(Box::new(sample_result())), wire);
            roundtrip_reply_wire(ClusterReply::Result(Box::new(traced_result())), wire);
            roundtrip_reply_wire(
                ClusterReply::Partial {
                    rank: 0,
                    layer: 1,
                    count: 32,
                    secs: 0.5,
                    values: vec![0.0, 2.0, 2.0],
                },
                wire,
            );
            roundtrip_reply_wire(ClusterReply::Error { message: "nope".into() }, wire);
        }
    }

    #[test]
    fn exchange_and_partial_use_the_v4_frame_kinds() {
        let req = ClusterRequest::Exchange {
            layer: 1,
            features: vec![0.5, 1.5],
            trace: TraceId::NONE,
        };
        let mut buf = Vec::new();
        write_request(&mut buf, &req, WireFormat::Bin).unwrap();
        assert_eq!(buf[4], 7, "exchange must use frame kind 7");

        let reply = ClusterReply::Partial {
            rank: 0,
            layer: 1,
            count: 2,
            secs: 0.25,
            values: vec![0.5, 1.5],
        };
        let mut buf = Vec::new();
        write_reply(&mut buf, &reply, WireFormat::Bin).unwrap();
        assert_eq!(buf[4], 8, "partial must use frame kind 8");

        // A partial frame is never a valid request.
        let err = read_invalid(&buf, 1 << 20);
        assert!(err.contains("reply"), "unexpected error: {err}");
    }

    #[test]
    fn sparse_exchange_panels_use_the_bitmap_encoding() {
        // Live post-ReLU panels keep the {0,v} bitmap benefit whenever
        // a layer saturates to a shared clip value (or goes all-zero).
        let feats = vec![0.0f32; 800];
        let req = ClusterRequest::Exchange { layer: 0, features: feats, trace: TraceId::NONE };
        let mut bin = Vec::new();
        write_request(&mut bin, &req, WireFormat::Bin).unwrap();
        // header + trace/layer/count meta + enc + value + bitmap.
        assert!(bin.len() <= 9 + 24 + 1 + 4 + 100, "frame too large: {} bytes", bin.len());
        let (back, _) = read_msg(&mut &bin[..], 1 << 20);
        assert_eq!(back, req);
    }

    #[test]
    fn untraced_frames_are_byte_identical_to_v2() {
        // A NONE trace must keep the exact v2 bytes — kind 1 shard
        // frames and kind 4 result frames — so v2 peers parse them.
        let req = ClusterRequest::Shard {
            start: 3,
            features: vec![0.5, 1.5],
            trace: TraceId::NONE,
        };
        let mut buf = Vec::new();
        write_request(&mut buf, &req, WireFormat::Bin).unwrap();
        assert_eq!(buf[4], 1, "untraced shard must stay frame kind 1");

        let mut buf = Vec::new();
        write_reply(&mut buf, &ClusterReply::Result(Box::new(sample_result())), WireFormat::Bin)
            .unwrap();
        assert_eq!(buf[4], 4, "untraced result must stay frame kind 4");

        // Traced messages move to the v3 kinds.
        let req = ClusterRequest::Shard {
            start: 3,
            features: vec![0.5, 1.5],
            trace: TraceId(7),
        };
        let mut buf = Vec::new();
        write_request(&mut buf, &req, WireFormat::Bin).unwrap();
        assert_eq!(buf[4], 5, "traced shard must use frame kind 5");

        let mut buf = Vec::new();
        write_reply(&mut buf, &ClusterReply::Result(Box::new(traced_result())), WireFormat::Bin)
            .unwrap();
        assert_eq!(buf[4], 6, "traced result must use frame kind 6");
    }

    #[test]
    fn untraced_json_omits_the_trace_fields() {
        // The optional fields must be absent (not empty) when untraced,
        // so the v2 JSON shapes are preserved byte-for-byte.
        let line = ClusterRequest::Shard {
            start: 0,
            features: vec![],
            trace: TraceId::NONE,
        }
        .to_json()
        .to_string();
        assert!(!line.contains("trace"), "unexpected trace field: {line}");
        let line = ClusterReply::Result(Box::new(sample_result())).to_json().to_string();
        assert!(!line.contains("trace") && !line.contains("spans"), "v2 shape changed: {line}");
    }

    #[test]
    fn shard_bits_are_identical_across_wires() {
        // The shortest-vs-packed equivalence: whatever f32 panel goes
        // in, both encodings hand back the exact same bits.
        Runner::new(32, 0xB1A5).run("wire-equivalence", |rng| {
            let rows = proptest::usize_in(rng, 0, 24);
            let feats = proptest::vec_f32(rng, rows * 16, -32.0, 32.0);
            let req = ClusterRequest::Shard { start: rows, features: feats, trace: TraceId::NONE };
            let mut bits: Vec<Vec<u32>> = Vec::new();
            for wire in [WireFormat::Json, WireFormat::Bin] {
                let mut buf = Vec::new();
                write_request(&mut buf, &req, wire).unwrap();
                let (back, got_wire) = read_msg(&mut &buf[..], 1 << 24);
                if got_wire != wire {
                    return Err(format!("dispatched as {got_wire}, wrote {wire}"));
                }
                match back {
                    ClusterRequest::Shard { features, .. } => {
                        bits.push(features.iter().map(|x| x.to_bits()).collect())
                    }
                    other => return Err(format!("wrong request {}", other.op())),
                }
            }
            if bits[0] != bits[1] {
                return Err("json and binary decode to different bits".into());
            }
            Ok(())
        });
    }

    #[test]
    fn binary_shard_is_at_least_3x_smaller_than_json() {
        // The acceptance bar of the binary transport: ≥3× fewer scatter
        // bytes than JSON for the same panel.
        let mut rng = Xoshiro256::new(7);
        let feats: Vec<f32> = (0..64 * 50).map(|_| rng.next_f32()).collect();
        let req = ClusterRequest::Shard { start: 0, features: feats, trace: TraceId::NONE };
        let mut json = Vec::new();
        write_request(&mut json, &req, WireFormat::Json).unwrap();
        let mut bin = Vec::new();
        write_request(&mut bin, &req, WireFormat::Bin).unwrap();
        assert!(
            json.len() >= 3 * bin.len(),
            "json {} bytes vs binary {} bytes",
            json.len(),
            bin.len()
        );
    }

    #[test]
    fn sparse_uniform_panels_encode_as_bitmaps() {
        // The challenge's thresholded {0,1} images: one bit per value
        // plus a single shared f32, instead of 4 bytes per value.
        let mut rng = Xoshiro256::new(11);
        let feats: Vec<f32> =
            (0..1000).map(|_| if rng.next_f32() < 0.3 { 1.0 } else { 0.0 }).collect();
        let req =
            ClusterRequest::Shard { start: 0, features: feats.clone(), trace: TraceId::NONE };
        let mut bin = Vec::new();
        write_request(&mut bin, &req, WireFormat::Bin).unwrap();
        // header + meta + enc + value + bitmap, nothing panel-sized.
        assert!(bin.len() <= 9 + 16 + 1 + 4 + 125, "frame too large: {} bytes", bin.len());
        let (back, _) = read_msg(&mut &bin[..], 1 << 20);
        match back {
            ClusterRequest::Shard { features, .. } => {
                assert_eq!(features.len(), feats.len());
                for (a, b) in features.iter().zip(&feats) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
            other => panic!("wrong request {other:?}"),
        }
        // JSON spends ~4 bytes per "0.0"/"1.0" value: the bitmap beats
        // the 3x acceptance bar with a wide margin on binary panels.
        let mut json = Vec::new();
        write_request(&mut json, &req, WireFormat::Json).unwrap();
        assert!(json.len() >= 3 * bin.len(), "json {} vs bin {}", json.len(), bin.len());
    }

    #[test]
    fn zero_sign_and_mixed_panels_round_trip_bit_exactly() {
        let panels: [Vec<f32>; 5] = [
            vec![],                          // empty shard
            vec![0.0; 9],                    // all-zero panel
            vec![-0.0; 6],                   // uniform on the -0.0 bits
            vec![0.0, -0.0, 1.5, 0.0, 1.5],  // -0.0 forces dense
            vec![2.5; 17],                   // uniform, non-multiple-of-8
        ];
        for feats in panels {
            let req =
                ClusterRequest::Shard { start: 1, features: feats.clone(), trace: TraceId::NONE };
            let mut bin = Vec::new();
            write_request(&mut bin, &req, WireFormat::Bin).unwrap();
            let (back, _) = read_msg(&mut &bin[..], 1 << 20);
            match back {
                ClusterRequest::Shard { features, .. } => {
                    assert_eq!(features.len(), feats.len(), "panel {feats:?}");
                    for (a, b) in features.iter().zip(&feats) {
                        assert_eq!(a.to_bits(), b.to_bits(), "panel {feats:?}");
                    }
                }
                other => panic!("wrong request {other:?}"),
            }
        }
    }

    /// A stream-level (fatal) failure: the connection must drop.
    fn read_fatal(buf: &[u8], cap: usize) -> String {
        format!("{:#}", read_request(&mut &buf[..], cap).unwrap_err())
    }

    /// A fully-consumed but invalid message: reply-and-continue.
    fn read_invalid(buf: &[u8], cap: usize) -> String {
        match read_request(&mut &buf[..], cap).unwrap() {
            ReadOutcome::Invalid(e, _) => format!("{e:#}"),
            ReadOutcome::Msg(req, _) => panic!("unexpectedly parsed a {} op", req.op()),
            ReadOutcome::Eof => panic!("unexpected EOF"),
        }
    }

    #[test]
    fn truncated_oversized_and_corrupt_frames_are_rejected_with_context() {
        // Distinct values force the dense encoding, so every byte count
        // below scales with the declared value count.
        let feats: Vec<f32> = (0..8).map(|i| i as f32 * 1.5 + 0.5).collect();
        let req = ClusterRequest::Shard { start: 0, features: feats, trace: TraceId::NONE };
        let mut buf = Vec::new();
        write_request(&mut buf, &req, WireFormat::Bin).unwrap();

        // Truncated payload: the stream itself is broken (fatal).
        let cut = &buf[..buf.len() - 3];
        let err = read_fatal(cut, 1 << 20);
        assert!(err.contains("truncated"), "unexpected error: {err}");

        // Corrupt magic: fatal.
        let mut bad = buf.clone();
        bad[1] = b'X';
        let err = read_fatal(&bad, 1 << 20);
        assert!(err.contains("magic"), "unexpected error: {err}");

        // Declared length past the cap: fatal, rejected before any
        // allocation.
        let err = read_fatal(&buf, 16);
        assert!(err.contains("exceeds the 16-byte frame cap"), "unexpected error: {err}");

        // A lying value count (larger than the payload holds): the
        // frame was fully consumed, so this is an invalid message the
        // server answers without dropping the connection.
        let mut lying = buf.clone();
        let count_at = FRAME_HEADER_BYTES + 8;
        lying[count_at..count_at + 8].copy_from_slice(&9999u64.to_le_bytes());
        let err = read_invalid(&lying, 1 << 20);
        assert!(err.contains("truncated"), "unexpected error: {err}");

        // A lying value count (smaller: trailing bytes in the frame) —
        // also fully consumed, also recoverable.
        let mut trailing = buf.clone();
        trailing[count_at..count_at + 8].copy_from_slice(&7u64.to_le_bytes());
        let err = read_invalid(&trailing, 1 << 20);
        assert!(err.contains("trailing"), "unexpected error: {err}");

        // A result frame is never a valid request.
        let mut reply = Vec::new();
        write_reply(&mut reply, &ClusterReply::Result(Box::new(sample_result())), WireFormat::Bin)
            .unwrap();
        let err = read_invalid(&reply, 1 << 20);
        assert!(err.contains("reply"), "unexpected error: {err}");

        // An unknown op on a complete JSON line is likewise invalid,
        // not fatal (v1 behavior preserved).
        let err = read_invalid(b"{\"op\":\"warp\"}\n", 1 << 20);
        assert!(err.contains("warp"), "unexpected error: {err}");
    }

    #[test]
    fn read_line_capped_enforces_the_cap() {
        let mut line = String::new();
        let n = read_line_capped(&mut &b"{\"op\":\"ping\"}\nrest"[..], &mut line, 64).unwrap();
        assert_eq!(n, 14);
        assert_eq!(line.trim(), "{\"op\":\"ping\"}");

        let giant = vec![b'x'; 100];
        let err = read_line_capped(&mut &giant[..], &mut String::new(), 64).unwrap_err();
        assert!(err.to_string().contains("64-byte frame cap"), "unexpected: {err}");

        assert_eq!(read_line_capped(&mut &b""[..], &mut String::new(), 64).unwrap(), 0);
    }

    #[test]
    fn data_frame_cap_is_generous_but_bounded() {
        assert!(data_frame_cap(0) >= CONTROL_FRAME_CAP);
        assert!(data_frame_cap(1024) > CONTROL_FRAME_CAP);
        assert!(data_frame_cap(usize::MAX) <= FRAME_CAP_CEILING);
        assert!(data_frame_cap(1024) <= data_frame_cap(65536));
    }

    #[test]
    fn f32_features_survive_the_wire_bit_exactly() {
        // Awkward values: subnormal-ish, repeating-fraction, and large.
        let feats: Vec<f32> = vec![0.1, 1.0 / 3.0, 1e-12, 31.999999, 0.0];
        let req =
            ClusterRequest::Shard { start: 0, features: feats.clone(), trace: TraceId::NONE };
        let back = ClusterRequest::parse_line(&req.to_json().to_string()).unwrap();
        match back {
            ClusterRequest::Shard { features, .. } => {
                assert_eq!(features.len(), feats.len());
                for (a, b) in features.iter().zip(&feats) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{a} != {b}");
                }
            }
            other => panic!("wrong request {other:?}"),
        }
    }

    #[test]
    fn model_spec_from_config_resolves_bias() {
        let cfg = RuntimeConfig { neurons: 1024, ..Default::default() };
        let m = ModelSpec::from_config(&cfg);
        // The resolved challenge bias for 1024 neurons, widened losslessly.
        assert_eq!(m.bias, (-0.3f32) as f64);
        assert_eq!(m.bias as f32, -0.3f32);
        assert_eq!(m.input_edges(10), 10 * 120 * 32 * 1024);
    }

    #[test]
    fn seeds_above_i64_max_round_trip() {
        let mut m = model();
        m.seed = u64::MAX; // serializes as -1, casts back losslessly
        roundtrip_request(ClusterRequest::Load {
            rank: 0,
            model: m,
            spec: spec(),
            prune: false,
            shard: None,
        });
    }

    #[test]
    fn malformed_lines_rejected() {
        assert!(ClusterRequest::parse_line("not json").is_err());
        assert!(ClusterRequest::parse_line(r#"{"op":"warp"}"#).is_err());
        assert!(ClusterRequest::parse_line(r#"{"op":"shard","start":0}"#).is_err());
        assert!(ClusterRequest::parse_line(r#"{"op":"hello","wire":"morse"}"#).is_err());
        assert!(ClusterReply::parse_line(r#"{"kind":"warp"}"#).is_err());
        assert!(ClusterReply::parse_line(r#"{"kind":"result","rank":0}"#).is_err());
    }

    #[test]
    fn shard_result_busy_secs() {
        let r = ShardResult {
            rank: 0,
            start: 0,
            count: 0,
            categories: vec![],
            activations: vec![],
            live_per_layer: vec![],
            layer_secs: vec![0.5, 0.25],
            edges_traversed: 0,
            secs: 1.0,
            trace: TraceId::NONE,
            spans: vec![],
        };
        assert_eq!(r.busy_secs(), 0.75);
    }
}
