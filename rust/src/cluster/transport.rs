//! Cluster wire protocol: JSON-lines over TCP between rank 0 and the
//! worker ranks.
//!
//! The framing is the same one the serving subsystem speaks
//! (`server::protocol`): one UTF-8 JSON object per `\n`-terminated line,
//! serialized through the dependency-light `util::json`. The verbs are
//! the collective vocabulary of the paper's multi-GPU model (§IV.C):
//!
//! ```text
//! {"op":"ping"}                                   liveness
//! {"op":"load","rank":R,"model":{...},"spec":{...},"prune":true}
//!                                                 replicate the weights
//! {"op":"shard","start":S,"features":[...]}       scatter one partition
//! {"op":"shutdown"}                               drain + exit
//! ```
//!
//! `load` ships the *recipe* for the weight replica (shape, topology,
//! seed, bias), not the weights themselves: every rank rebuilds the full
//! weight set locally — replication without moving gigabytes through
//! rank 0. `shard` then moves only this rank's feature partition, and
//! the `result` reply carries the surviving categories, their final
//! activations, and the per-layer trajectory rank 0 aggregates into the
//! cluster imbalance report.
//!
//! Floats survive the wire bit-exactly: an `f32` widened to `f64`
//! serializes via Rust's shortest-round-trip formatting and parses back
//! to the identical bits, which is what makes cluster inference
//! bit-identical to the single-process run.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::NativeSpec;
use crate::engine::EngineKind;
use crate::server::protocol::parse_f32_array;
use crate::util::config::RuntimeConfig;
use crate::util::json::Json;

pub const CLUSTER_PROTOCOL_VERSION: i64 = 1;

/// The recipe a worker rank needs to materialise its full weight
/// replica: deterministic topology generation, not weight shipping.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelSpec {
    pub neurons: usize,
    pub layers: usize,
    pub k: usize,
    pub topology: String,
    pub seed: u64,
    /// Resolved bias constant (one value per neuron).
    pub bias: f64,
}

impl ModelSpec {
    pub fn from_config(cfg: &RuntimeConfig) -> ModelSpec {
        ModelSpec {
            neurons: cfg.neurons,
            layers: cfg.layers,
            k: cfg.k,
            topology: cfg.topology.clone(),
            seed: cfg.seed,
            bias: cfg.bias_value() as f64,
        }
    }

    /// Input edges of one full pass over `batch` features.
    pub fn input_edges(&self, batch: usize) -> u64 {
        batch as u64 * self.layers as u64 * (self.k as u64 * self.neurons as u64)
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("neurons", Json::Int(self.neurons as i64)),
            ("layers", Json::Int(self.layers as i64)),
            ("k", Json::Int(self.k as i64)),
            ("topology", Json::Str(self.topology.clone())),
            ("seed", Json::Int(self.seed as i64)),
            ("bias", Json::Num(self.bias)),
        ])
    }

    fn from_json(j: &Json) -> Result<ModelSpec> {
        Ok(ModelSpec {
            neurons: j.req_usize("neurons")?,
            layers: j.req_usize("layers")?,
            k: j.req_usize("k")?,
            topology: j.req_str("topology")?.to_string(),
            // The full u64 seed range round-trips through i64 bits (a
            // seed above i64::MAX serializes negative and casts back).
            seed: j
                .req("seed")?
                .as_i64()
                .ok_or_else(|| anyhow!("\"seed\" is not an integer"))?
                as u64,
            bias: j.req_f64("bias")?,
        })
    }
}

fn spec_to_json(spec: &NativeSpec) -> Json {
    Json::obj(vec![
        ("engine", Json::Str(spec.engine.as_str().to_string())),
        ("minibatch", Json::Int(spec.minibatch as i64)),
        ("slice", Json::Int(spec.slice as i64)),
        ("threads", Json::Int(spec.threads as i64)),
    ])
}

fn spec_from_json(j: &Json) -> Result<NativeSpec> {
    Ok(NativeSpec {
        engine: EngineKind::parse(j.req_str("engine")?)?,
        minibatch: j.req_usize("minibatch")?,
        slice: j.req_usize("slice")?,
        threads: j.req_usize("threads")?,
    })
}

/// One coordinator-to-worker request.
#[derive(Clone, Debug, PartialEq)]
pub enum ClusterRequest {
    Ping,
    /// Build the full weight replica on this rank.
    Load { rank: usize, model: ModelSpec, spec: NativeSpec, prune: bool },
    /// Run all layers over one statically-partitioned feature shard.
    Shard { start: usize, features: Vec<f32> },
    /// Finish the current work and exit the worker process.
    Shutdown,
}

impl ClusterRequest {
    pub fn to_json(&self) -> Json {
        match self {
            ClusterRequest::Ping => Json::obj(vec![("op", Json::Str("ping".into()))]),
            ClusterRequest::Load { rank, model, spec, prune } => Json::obj(vec![
                ("op", Json::Str("load".into())),
                ("rank", Json::Int(*rank as i64)),
                ("model", model.to_json()),
                ("spec", spec_to_json(spec)),
                ("prune", Json::Bool(*prune)),
            ]),
            ClusterRequest::Shard { start, features } => {
                let xs: Vec<f64> = features.iter().map(|&x| x as f64).collect();
                Json::obj(vec![
                    ("op", Json::Str("shard".into())),
                    ("start", Json::Int(*start as i64)),
                    ("features", Json::arr_f64(&xs)),
                ])
            }
            ClusterRequest::Shutdown => Json::obj(vec![("op", Json::Str("shutdown".into()))]),
        }
    }

    pub fn parse_line(line: &str) -> Result<ClusterRequest> {
        let v = Json::parse(line).context("cluster request is not valid JSON")?;
        match v.req_str("op")? {
            "ping" => Ok(ClusterRequest::Ping),
            "load" => Ok(ClusterRequest::Load {
                rank: v.req_usize("rank")?,
                model: ModelSpec::from_json(v.req("model")?).context("\"model\"")?,
                spec: spec_from_json(v.req("spec")?).context("\"spec\"")?,
                prune: v
                    .req("prune")?
                    .as_bool()
                    .ok_or_else(|| anyhow!("\"prune\" is not a bool"))?,
            }),
            "shard" => Ok(ClusterRequest::Shard {
                start: v.req_usize("start")?,
                features: parse_f32_array(v.req("features")?).context("\"features\"")?,
            }),
            "shutdown" => Ok(ClusterRequest::Shutdown),
            other => bail!("unknown cluster op {other:?}"),
        }
    }
}

/// What one rank computed for its shard: the gather payload plus the
/// per-layer trajectory the coordinator folds into the imbalance report.
#[derive(Clone, Debug, PartialEq)]
pub struct ShardResult {
    pub rank: usize,
    /// Global id of the shard's first feature (echoed for cover checks).
    pub start: usize,
    /// Features assigned to this shard (echoed for cover checks).
    pub count: usize,
    /// Surviving global feature ids, ascending.
    pub categories: Vec<usize>,
    /// Compacted final activations `[categories.len(), neurons]`.
    pub activations: Vec<f32>,
    /// Live features entering each layer.
    pub live_per_layer: Vec<usize>,
    /// Seconds per layer on this rank.
    pub layer_secs: Vec<f64>,
    pub edges_traversed: u64,
    /// Whole-shard wall seconds on the worker (compute, not transport).
    pub secs: f64,
}

impl ShardResult {
    pub fn busy_secs(&self) -> f64 {
        self.layer_secs.iter().sum()
    }
}

/// One worker-to-coordinator reply.
#[derive(Clone, Debug, PartialEq)]
pub enum ClusterReply {
    Pong { version: i64 },
    Loaded { rank: usize, neurons: usize, layers: usize },
    Result(Box<ShardResult>),
    /// Acknowledgement of a shutdown; the worker exits after sending it.
    Bye,
    Error { message: String },
}

impl ClusterReply {
    pub fn to_json(&self) -> Json {
        match self {
            ClusterReply::Pong { version } => Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("kind", Json::Str("pong".into())),
                ("version", Json::Int(*version)),
            ]),
            ClusterReply::Loaded { rank, neurons, layers } => Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("kind", Json::Str("loaded".into())),
                ("rank", Json::Int(*rank as i64)),
                ("neurons", Json::Int(*neurons as i64)),
                ("layers", Json::Int(*layers as i64)),
            ]),
            ClusterReply::Result(r) => {
                let acts: Vec<f64> = r.activations.iter().map(|&x| x as f64).collect();
                Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    ("kind", Json::Str("result".into())),
                    ("rank", Json::Int(r.rank as i64)),
                    ("start", Json::Int(r.start as i64)),
                    ("count", Json::Int(r.count as i64)),
                    ("categories", Json::arr_usize(&r.categories)),
                    ("activations", Json::arr_f64(&acts)),
                    ("live_per_layer", Json::arr_usize(&r.live_per_layer)),
                    ("layer_secs", Json::arr_f64(&r.layer_secs)),
                    ("edges_traversed", Json::Int(r.edges_traversed as i64)),
                    ("secs", Json::Num(r.secs)),
                ])
            }
            ClusterReply::Bye => Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("kind", Json::Str("bye".into())),
            ]),
            ClusterReply::Error { message } => Json::obj(vec![
                ("ok", Json::Bool(false)),
                ("kind", Json::Str("error".into())),
                ("error", Json::Str(message.clone())),
            ]),
        }
    }

    pub fn parse_line(line: &str) -> Result<ClusterReply> {
        let v = Json::parse(line).context("cluster reply is not valid JSON")?;
        match v.req_str("kind")? {
            "pong" => Ok(ClusterReply::Pong {
                version: v
                    .req("version")?
                    .as_i64()
                    .ok_or_else(|| anyhow!("\"version\" is not an int"))?,
            }),
            "loaded" => Ok(ClusterReply::Loaded {
                rank: v.req_usize("rank")?,
                neurons: v.req_usize("neurons")?,
                layers: v.req_usize("layers")?,
            }),
            "result" => Ok(ClusterReply::Result(Box::new(ShardResult {
                rank: v.req_usize("rank")?,
                start: v.req_usize("start")?,
                count: v.req_usize("count")?,
                categories: parse_usize_array(v.req("categories")?).context("\"categories\"")?,
                activations: parse_f32_array(v.req("activations")?).context("\"activations\"")?,
                live_per_layer: parse_usize_array(v.req("live_per_layer")?)
                    .context("\"live_per_layer\"")?,
                layer_secs: parse_f64_array(v.req("layer_secs")?).context("\"layer_secs\"")?,
                edges_traversed: v.req_usize("edges_traversed")? as u64,
                secs: v.req_f64("secs")?,
            }))),
            "bye" => Ok(ClusterReply::Bye),
            "error" => Ok(ClusterReply::Error { message: v.req_str("error")?.to_string() }),
            other => bail!("unknown cluster reply kind {other:?}"),
        }
    }
}

fn parse_usize_array(j: &Json) -> Result<Vec<usize>> {
    let arr = j.as_arr().ok_or_else(|| anyhow!("expected an array of unsigned ints"))?;
    arr.iter()
        .map(|x| x.as_usize().ok_or_else(|| anyhow!("array element is not an unsigned int")))
        .collect()
}

fn parse_f64_array(j: &Json) -> Result<Vec<f64>> {
    let arr = j.as_arr().ok_or_else(|| anyhow!("expected an array of numbers"))?;
    arr.iter()
        .map(|x| {
            let f = x.as_f64().ok_or_else(|| anyhow!("array element is not a number"))?;
            if !f.is_finite() {
                bail!("array element is not finite");
            }
            Ok(f)
        })
        .collect()
}

/// Blocking JSON-lines client held by rank 0, one per worker rank.
pub struct ClusterClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl ClusterClient {
    pub fn connect(addr: SocketAddr) -> Result<ClusterClient> {
        let stream =
            TcpStream::connect(addr).with_context(|| format!("connecting to rank at {addr}"))?;
        stream.set_nodelay(true).ok();
        let writer = stream.try_clone().context("cloning cluster stream")?;
        Ok(ClusterClient { reader: BufReader::new(stream), writer })
    }

    /// Send one request and block for its reply line.
    pub fn call(&mut self, req: &ClusterRequest) -> Result<ClusterReply> {
        writeln!(self.writer, "{}", req.to_json()).context("writing cluster request")?;
        self.writer.flush().context("flushing cluster request")?;
        let mut line = String::new();
        let n = self.reader.read_line(&mut line).context("reading cluster reply")?;
        if n == 0 {
            bail!("worker closed the connection");
        }
        ClusterReply::parse_line(line.trim())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> ModelSpec {
        ModelSpec {
            neurons: 64,
            layers: 5,
            k: 4,
            topology: "butterfly".into(),
            seed: 7,
            bias: -0.3,
        }
    }

    fn spec() -> NativeSpec {
        NativeSpec { engine: EngineKind::Sliced, minibatch: 12, slice: 32, threads: 2 }
    }

    fn roundtrip_request(req: ClusterRequest) {
        let line = req.to_json().to_string();
        assert_eq!(ClusterRequest::parse_line(&line).unwrap(), req, "line: {line}");
    }

    fn roundtrip_reply(reply: ClusterReply) {
        let line = reply.to_json().to_string();
        assert_eq!(ClusterReply::parse_line(&line).unwrap(), reply, "line: {line}");
    }

    #[test]
    fn request_roundtrips() {
        roundtrip_request(ClusterRequest::Ping);
        roundtrip_request(ClusterRequest::Load {
            rank: 3,
            model: model(),
            spec: spec(),
            prune: true,
        });
        roundtrip_request(ClusterRequest::Shard {
            start: 12,
            features: vec![0.0, 1.5, 0.25, 3.125],
        });
        roundtrip_request(ClusterRequest::Shutdown);
    }

    #[test]
    fn reply_roundtrips() {
        roundtrip_reply(ClusterReply::Pong { version: CLUSTER_PROTOCOL_VERSION });
        roundtrip_reply(ClusterReply::Loaded { rank: 1, neurons: 64, layers: 5 });
        roundtrip_reply(ClusterReply::Result(Box::new(ShardResult {
            rank: 2,
            start: 8,
            count: 4,
            categories: vec![9, 11],
            activations: vec![0.5, 0.0, 1.25, 32.0],
            live_per_layer: vec![4, 3, 2, 2, 2],
            layer_secs: vec![0.25, 0.125, 0.0625, 0.5, 0.125],
            edges_traversed: 1234,
            secs: 1.5,
        })));
        roundtrip_reply(ClusterReply::Bye);
        roundtrip_reply(ClusterReply::Error { message: "boom".into() });
    }

    #[test]
    fn f32_features_survive_the_wire_bit_exactly() {
        // Awkward values: subnormal-ish, repeating-fraction, and large.
        let feats: Vec<f32> = vec![0.1, 1.0 / 3.0, 1e-12, 31.999999, 0.0];
        let req = ClusterRequest::Shard { start: 0, features: feats.clone() };
        let back = ClusterRequest::parse_line(&req.to_json().to_string()).unwrap();
        match back {
            ClusterRequest::Shard { features, .. } => {
                assert_eq!(features.len(), feats.len());
                for (a, b) in features.iter().zip(&feats) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{a} != {b}");
                }
            }
            other => panic!("wrong request {other:?}"),
        }
    }

    #[test]
    fn model_spec_from_config_resolves_bias() {
        let cfg = RuntimeConfig { neurons: 1024, ..Default::default() };
        let m = ModelSpec::from_config(&cfg);
        // The resolved challenge bias for 1024 neurons, widened losslessly.
        assert_eq!(m.bias, (-0.3f32) as f64);
        assert_eq!(m.bias as f32, -0.3f32);
        assert_eq!(m.input_edges(10), 10 * 120 * 32 * 1024);
    }

    #[test]
    fn seeds_above_i64_max_round_trip() {
        let mut m = model();
        m.seed = u64::MAX; // serializes as -1, casts back losslessly
        roundtrip_request(ClusterRequest::Load {
            rank: 0,
            model: m,
            spec: spec(),
            prune: false,
        });
    }

    #[test]
    fn malformed_lines_rejected() {
        assert!(ClusterRequest::parse_line("not json").is_err());
        assert!(ClusterRequest::parse_line(r#"{"op":"warp"}"#).is_err());
        assert!(ClusterRequest::parse_line(r#"{"op":"shard","start":0}"#).is_err());
        assert!(ClusterReply::parse_line(r#"{"kind":"warp"}"#).is_err());
        assert!(ClusterReply::parse_line(r#"{"kind":"result","rank":0}"#).is_err());
    }

    #[test]
    fn shard_result_busy_secs() {
        let r = ShardResult {
            rank: 0,
            start: 0,
            count: 0,
            categories: vec![],
            activations: vec![],
            live_per_layer: vec![],
            layer_secs: vec![0.5, 0.25],
            edges_traversed: 0,
            secs: 1.0,
        };
        assert_eq!(r.busy_secs(), 0.75);
    }
}
