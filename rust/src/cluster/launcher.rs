//! Local process launcher: spawns and supervises worker-rank processes.
//!
//! Each rank is a real OS process (`<program> cluster-worker --listen
//! host:0`), so the cluster exercises genuine process isolation — the
//! thing `coordinator::pool`'s threads only simulate. The launcher owns
//! the child handles:
//!
//! * **readiness** — a worker announces `SPDNN-CLUSTER-WORKER <addr>` on
//!   stdout; the launcher scrapes it (with a timeout) before reporting
//!   the rank as up, and keeps draining the pipe afterwards so a chatty
//!   worker can never block on a full pipe;
//! * **eager death detection** — the same stdout-drain thread flips a
//!   shared [`RankHealth`] flag the moment the pipe hits EOF (the OS
//!   closes it when the process dies), so supervisors — notably the
//!   cluster-backed serving tier — observe a dead rank within
//!   milliseconds of the exit instead of at the next gather;
//! * **failure propagation** — `check()` turns an exited child into an
//!   error naming the rank and exit status, so the coordinator surfaces
//!   dead ranks instead of hanging on half a cluster;
//! * **clean shutdown** — after the coordinator sends `shutdown` ops,
//!   `wait_exit` reaps every child within a deadline and reports any
//!   rank that had to be killed; `Drop` kills whatever is left so a
//!   failed run cannot leak processes.

use std::io::{BufRead, BufReader};
use std::net::SocketAddr;
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::rank::READY_PREFIX;
use crate::log_info;

/// Shared, clonable liveness view of a rank fleet. One flag per rank,
/// flipped to dead by the launcher's stdout-drain thread the moment the
/// worker's pipe hits EOF (which the OS delivers when the process
/// exits, cleanly or not) — the eager counterpart of polling
/// `Child::try_wait` at gather time. `kill_rank` flips the flag
/// synchronously so a deliberate kill is visible before the reader
/// thread wakes.
#[derive(Clone)]
pub struct RankHealth {
    alive: Arc<Vec<AtomicBool>>,
}

impl RankHealth {
    fn new(ranks: usize) -> RankHealth {
        RankHealth { alive: Arc::new((0..ranks).map(|_| AtomicBool::new(true)).collect()) }
    }

    /// Liveness of one rank (out-of-range ranks read as dead).
    pub fn alive(&self, rank: usize) -> bool {
        self.alive.get(rank).map(|a| a.load(Ordering::Acquire)).unwrap_or(false)
    }

    pub fn ranks(&self) -> usize {
        self.alive.len()
    }

    /// Ranks currently marked dead, ascending.
    pub fn dead_ranks(&self) -> Vec<usize> {
        (0..self.alive.len()).filter(|&r| !self.alive(r)).collect()
    }

    pub fn all_alive(&self) -> bool {
        self.alive.iter().all(|a| a.load(Ordering::Acquire))
    }

    fn mark_dead(&self, rank: usize) {
        if let Some(a) = self.alive.get(rank) {
            a.store(false, Ordering::Release);
        }
    }

    /// Flip a rank back to alive — only the launcher's respawn path
    /// does this, immediately before the replacement process spawns, so
    /// healers never see a respawned rank still flagged dead.
    fn mark_alive(&self, rank: usize) {
        if let Some(a) = self.alive.get(rank) {
            a.store(true, Ordering::Release);
        }
    }
}

/// How the launcher starts a local rank fleet.
#[derive(Clone, Debug)]
pub struct LauncherConfig {
    /// The spdnn binary to run (`std::env::current_exe()` for the CLI;
    /// `env!("CARGO_BIN_EXE_spdnn")` in tests and benches).
    pub program: PathBuf,
    /// Worker-rank count (rank 0 is the coordinating caller itself).
    pub ranks: usize,
    /// Interface workers bind on (port 0 → each picks a free port).
    pub host: String,
    /// Longest a worker may take to announce readiness.
    pub ready_timeout: Duration,
}

impl LauncherConfig {
    pub fn local(program: PathBuf, ranks: usize) -> LauncherConfig {
        LauncherConfig {
            program,
            ranks,
            host: "127.0.0.1".to_string(),
            ready_timeout: Duration::from_secs(20),
        }
    }
}

/// One supervised worker process.
struct WorkerProc {
    rank: usize,
    addr: SocketAddr,
    child: Child,
}

/// A running local rank fleet.
pub struct Launcher {
    /// Kept for `respawn_rank`: a replacement process is spawned with
    /// the same program/host/timeout the fleet started with.
    cfg: LauncherConfig,
    workers: Vec<WorkerProc>,
    /// Ranks removed by `kill_rank` and not yet respawned: the fleet is
    /// degraded (partitioning still counts them), so `check` keeps
    /// failing with a diagnostic naming the rank instead of an opaque
    /// socket error. `respawn_rank` fills the hole.
    killed: Vec<usize>,
    health: RankHealth,
}

impl Launcher {
    /// Spawn `cfg.ranks` worker processes and wait for every readiness
    /// announcement. On any failure the already-spawned ranks are killed.
    pub fn spawn(cfg: &LauncherConfig) -> Result<Launcher> {
        if cfg.ranks == 0 {
            bail!("cluster needs at least one worker rank");
        }
        let health = RankHealth::new(cfg.ranks);
        let mut workers: Vec<WorkerProc> = Vec::with_capacity(cfg.ranks);
        for rank in 0..cfg.ranks {
            match spawn_worker(cfg, rank, health.clone()) {
                Ok(w) => workers.push(w),
                Err(e) => {
                    for w in &mut workers {
                        let _ = w.child.kill();
                        let _ = w.child.wait();
                    }
                    return Err(e);
                }
            }
        }
        Ok(Launcher { cfg: cfg.clone(), workers, killed: Vec::new(), health })
    }

    /// Worker-rank count.
    pub fn ranks(&self) -> usize {
        self.workers.len()
    }

    /// Bound address of every rank, in rank order.
    pub fn addrs(&self) -> Vec<SocketAddr> {
        self.workers.iter().map(|w| w.addr).collect()
    }

    /// Shared liveness flags: supervisors clone this and observe rank
    /// death eagerly (stdout-EOF) instead of at the next gather.
    pub fn health(&self) -> RankHealth {
        self.health.clone()
    }

    /// Propagate failures: error if any rank's process was killed or
    /// has exited on its own. The eager health flags are consulted
    /// first, so a death the drain thread already observed surfaces
    /// without a `try_wait` syscall per rank.
    pub fn check(&mut self) -> Result<()> {
        if let Some(rank) = self.killed.first() {
            bail!("worker rank {rank} was killed and not replaced");
        }
        if let Some(&rank) = self.health.dead_ranks().first() {
            bail!("worker rank {rank} died (stdout closed)");
        }
        for w in &mut self.workers {
            if let Some(status) = w.child.try_wait().context("polling worker process")? {
                self.health.mark_dead(w.rank);
                bail!("worker rank {} exited early ({status})", w.rank);
            }
        }
        Ok(())
    }

    /// Kill one rank outright (fault-injection hook for tests). The
    /// launcher remembers the hole: subsequent `check` calls fail.
    pub fn kill_rank(&mut self, rank: usize) -> Result<()> {
        let idx = self
            .workers
            .iter()
            .position(|w| w.rank == rank)
            .ok_or_else(|| anyhow::anyhow!("no live worker rank {rank}"))?;
        let mut w = self.workers.remove(idx);
        w.child.kill().with_context(|| format!("killing rank {rank}"))?;
        w.child.wait().with_context(|| format!("reaping rank {rank}"))?;
        self.health.mark_dead(rank);
        self.killed.push(rank);
        Ok(())
    }

    /// Spawn a replacement process for a dead rank and return its bound
    /// address: the healing half of `kill_rank`. Any stale child handle
    /// for the rank (a worker that died on its own and was never
    /// reaped) is reaped first, the health flag flips back to alive,
    /// and the rank leaves the `killed` hole list — so `check` passes
    /// again once every dead rank has been replaced.
    pub fn respawn_rank(&mut self, rank: usize) -> Result<SocketAddr> {
        if rank >= self.cfg.ranks {
            bail!("no rank {rank} in a {}-rank fleet", self.cfg.ranks);
        }
        if let Some(idx) = self.workers.iter().position(|w| w.rank == rank) {
            let mut w = self.workers.remove(idx);
            let _ = w.child.kill();
            let _ = w.child.wait();
        }
        // Alive before the spawn: the replacement's own drain thread
        // owns the flag from here, and flips it back on EOF if the new
        // process dies too.
        self.health.mark_alive(rank);
        let worker = match spawn_worker(&self.cfg, rank, self.health.clone()) {
            Ok(w) => w,
            Err(e) => {
                self.health.mark_dead(rank);
                return Err(e.context(format!("respawning worker rank {rank}")));
            }
        };
        let addr = worker.addr;
        self.workers.push(worker);
        self.killed.retain(|&r| r != rank);
        log_info!("respawned worker rank {rank} at {addr}");
        Ok(addr)
    }

    /// Reap every child within `timeout` (call after the coordinator has
    /// sent shutdown ops). Ranks that do not exit in time are killed and
    /// reported as an unclean shutdown. Idempotent: the worker list is
    /// cleared, so a second call is a no-op (`&mut self` rather than
    /// by-value so supervisors can keep the launcher behind a shared
    /// lock for respawns right up to shutdown).
    pub fn wait_exit(&mut self, timeout: Duration) -> Result<()> {
        let deadline = Instant::now() + timeout;
        let mut failures: Vec<String> = Vec::new();
        for w in &mut self.workers {
            loop {
                match w.child.try_wait() {
                    Ok(Some(status)) => {
                        if !status.success() {
                            failures.push(format!("rank {} exited with {status}", w.rank));
                        }
                        break;
                    }
                    Ok(None) => {
                        if Instant::now() >= deadline {
                            let _ = w.child.kill();
                            let _ = w.child.wait();
                            failures.push(format!(
                                "rank {} ignored shutdown and was killed",
                                w.rank
                            ));
                            break;
                        }
                        std::thread::sleep(Duration::from_millis(5));
                    }
                    Err(e) => {
                        failures.push(format!("rank {}: {e}", w.rank));
                        break;
                    }
                }
            }
        }
        self.workers.clear();
        if failures.is_empty() {
            Ok(())
        } else {
            bail!("cluster shutdown was not clean: {}", failures.join("; "))
        }
    }
}

impl Drop for Launcher {
    fn drop(&mut self) {
        for w in &mut self.workers {
            let _ = w.child.kill();
            let _ = w.child.wait();
        }
    }
}

fn spawn_worker(cfg: &LauncherConfig, rank: usize, health: RankHealth) -> Result<WorkerProc> {
    let mut child = Command::new(&cfg.program)
        .arg("cluster-worker")
        .arg("--listen")
        .arg(format!("{}:0", cfg.host))
        .stdin(Stdio::null())
        .stdout(Stdio::piped())
        .stderr(Stdio::inherit())
        .spawn()
        .with_context(|| {
            format!("spawning worker rank {rank} ({})", cfg.program.display())
        })?;
    let stdout = child.stdout.take().expect("piped stdout");

    // The reader thread scrapes the readiness line, then keeps draining
    // stdout for the worker's lifetime (forwarding to our stderr) so the
    // pipe can never fill up and block the worker. The same thread is
    // the eager death detector: stdout EOF means the process is gone,
    // and the shared health flag flips before anyone polls `try_wait`.
    let (tx, rx) = mpsc::channel::<Result<SocketAddr, String>>();
    std::thread::spawn(move || {
        let mut reader = BufReader::new(stdout);
        let mut line = String::new();
        let mut announced = false;
        loop {
            line.clear();
            match reader.read_line(&mut line) {
                Ok(0) => {
                    health.mark_dead(rank);
                    if !announced {
                        let _ = tx.send(Err("exited before announcing readiness".to_string()));
                    }
                    break;
                }
                Ok(_) => {
                    let t = line.trim();
                    if !announced {
                        if let Some(rest) = t.strip_prefix(READY_PREFIX) {
                            announced = true;
                            let _ = tx.send(
                                rest.trim()
                                    .parse::<SocketAddr>()
                                    .map_err(|e| format!("bad ready line {t:?}: {e}")),
                            );
                            continue;
                        }
                    }
                    if !t.is_empty() {
                        // Forward worker chatter through the logger so
                        // SPDNN_LOG filters it like everything else; the
                        // rank tag keeps interleaved fleets attributable.
                        log_info!("[rank {rank}] {t}");
                    }
                }
                Err(_) => {
                    health.mark_dead(rank);
                    break;
                }
            }
        }
    });

    match rx.recv_timeout(cfg.ready_timeout) {
        Ok(Ok(addr)) => Ok(WorkerProc { rank, addr, child }),
        Ok(Err(msg)) => {
            let _ = child.kill();
            let _ = child.wait();
            bail!("worker rank {rank}: {msg}")
        }
        Err(_) => {
            let _ = child.kill();
            let _ = child.wait();
            bail!(
                "worker rank {rank} did not announce readiness within {:?}",
                cfg.ready_timeout
            )
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_ranks_rejected() {
        let cfg = LauncherConfig::local(PathBuf::from("/bin/true"), 0);
        assert!(Launcher::spawn(&cfg).is_err());
    }

    #[test]
    fn missing_program_is_a_spawn_error() {
        let cfg = LauncherConfig::local(PathBuf::from("/nonexistent/spdnn"), 1);
        assert!(Launcher::spawn(&cfg).is_err());
    }

    #[test]
    fn non_announcing_program_times_out_or_errors() {
        // `/bin/true` exits immediately without the ready line: the
        // reader thread reports the early exit, not a hang.
        let mut cfg = LauncherConfig::local(PathBuf::from("/bin/true"), 1);
        cfg.ready_timeout = Duration::from_secs(5);
        let err = Launcher::spawn(&cfg).unwrap_err().to_string();
        assert!(err.contains("rank 0"), "unexpected error: {err}");
    }

    #[test]
    fn rank_health_flags_start_alive_and_flip_once() {
        let h = RankHealth::new(3);
        assert!(h.all_alive());
        assert_eq!(h.ranks(), 3);
        assert!(h.alive(2));
        assert!(!h.alive(3), "out-of-range ranks read as dead");
        h.mark_dead(1);
        assert!(!h.all_alive());
        assert!(!h.alive(1));
        assert_eq!(h.dead_ranks(), vec![1]);
        // Clones observe the same flags (shared Arc).
        let clone = h.clone();
        clone.mark_dead(0);
        assert_eq!(h.dead_ranks(), vec![0, 1]);
    }
}
