//! A worker rank: one OS process holding a full weight replica and
//! executing the layer loop over whatever feature shard rank 0 scatters
//! to it (paper §IV.C — weights duplicated, features partitioned).
//!
//! The process is started as `spdnn cluster-worker --listen HOST:PORT`
//! (port 0 picks a free port), announces its bound address on stdout as
//! `SPDNN-CLUSTER-WORKER <addr>` for the launcher to scrape, then serves
//! coordinator connections sequentially until a `shutdown` op arrives.
//!
//! The compute path is exactly the in-process one: a `load` op rebuilds
//! the weight set deterministically (same RadixNet topology + seed as
//! rank 0, so replication costs generation time, not network transfer),
//! and every `shard` op becomes a `coordinator::worker::WorkerTask` run
//! through `run_worker` on the v2 engines — which is what makes cluster
//! output bit-identical to single-process inference.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::coordinator::{BackendKind, NativeSpec, WeightSource, WorkerTask};
use crate::formats::EllMatrix;
use crate::radixnet::{RadixNet, Topology};
use crate::{log_info, log_warn};

use super::transport::{ClusterReply, ClusterRequest, ModelSpec, CLUSTER_PROTOCOL_VERSION};

/// First stdout line of a worker: `SPDNN-CLUSTER-WORKER <addr>`.
pub const READY_PREFIX: &str = "SPDNN-CLUSTER-WORKER";

/// The weight replica plus the engine configuration a `load` op pinned.
struct Replica {
    rank: usize,
    model: ModelSpec,
    spec: NativeSpec,
    prune: bool,
    layers: Arc<Vec<EllMatrix>>,
    bias: Vec<f32>,
}

/// Build the full weight set for `model` (deterministic replication:
/// every rank generates identical layers from the shared recipe).
pub fn build_replica_weights(model: &ModelSpec) -> Result<(Vec<EllMatrix>, Vec<f32>)> {
    let topo = Topology::parse(&model.topology)?;
    let net = RadixNet::new(model.neurons, model.layers, model.k, topo, model.seed)?;
    let layers: Vec<EllMatrix> = (0..model.layers).map(|l| net.layer_ell(l)).collect();
    let bias = vec![model.bias as f32; model.neurons];
    Ok((layers, bias))
}

enum ConnOutcome {
    /// Peer disconnected; go back to accept.
    Disconnected,
    /// A shutdown op was acknowledged; the process should exit.
    Shutdown,
}

/// Serve one worker rank until a `shutdown` op arrives. Announces the
/// bound address on stdout first (the launcher's readiness handshake).
pub fn serve_rank(listener: TcpListener) -> Result<()> {
    let addr = listener.local_addr().context("resolving bound address")?;
    println!("{READY_PREFIX} {addr}");
    std::io::stdout().flush().ok();

    let mut replica: Option<Replica> = None;
    loop {
        let (stream, peer) = listener.accept().context("accepting coordinator connection")?;
        log_info!("cluster worker: coordinator connected from {peer}");
        match serve_connection(stream, &mut replica) {
            Ok(ConnOutcome::Shutdown) => {
                log_info!("cluster worker: shutdown acknowledged, exiting");
                return Ok(());
            }
            Ok(ConnOutcome::Disconnected) => {}
            Err(e) => log_warn!("cluster worker: connection error: {e:#}"),
        }
    }
}

fn serve_connection(stream: TcpStream, replica: &mut Option<Replica>) -> Result<ConnOutcome> {
    stream.set_nodelay(true).ok();
    let mut writer = stream.try_clone().context("cloning connection")?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    loop {
        line.clear();
        let n = reader.read_line(&mut line).context("reading request line")?;
        if n == 0 {
            return Ok(ConnOutcome::Disconnected);
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let (reply, shutdown) = match ClusterRequest::parse_line(trimmed) {
            Ok(ClusterRequest::Ping) => {
                (ClusterReply::Pong { version: CLUSTER_PROTOCOL_VERSION }, false)
            }
            Ok(ClusterRequest::Load { rank, model, spec, prune }) => {
                match load_replica(rank, model, spec, prune) {
                    Ok(r) => {
                        let reply = ClusterReply::Loaded {
                            rank: r.rank,
                            neurons: r.model.neurons,
                            layers: r.model.layers,
                        };
                        *replica = Some(r);
                        (reply, false)
                    }
                    Err(e) => (ClusterReply::Error { message: format!("{e:#}") }, false),
                }
            }
            Ok(ClusterRequest::Shard { start, features }) => match replica.as_ref() {
                Some(r) => match run_shard(r, start, features) {
                    Ok(result) => (ClusterReply::Result(Box::new(result)), false),
                    Err(e) => (ClusterReply::Error { message: format!("{e:#}") }, false),
                },
                None => (
                    ClusterReply::Error {
                        message: "no model loaded on this rank (send a load op first)".into(),
                    },
                    false,
                ),
            },
            Ok(ClusterRequest::Shutdown) => (ClusterReply::Bye, true),
            Err(e) => (ClusterReply::Error { message: format!("{e:#}") }, false),
        };
        writeln!(writer, "{}", reply.to_json()).context("writing reply")?;
        writer.flush().ok();
        if shutdown {
            return Ok(ConnOutcome::Shutdown);
        }
    }
}

fn load_replica(rank: usize, model: ModelSpec, spec: NativeSpec, prune: bool) -> Result<Replica> {
    let t = Instant::now();
    let (layers, bias) = build_replica_weights(&model)?;
    log_info!(
        "cluster worker rank {rank}: replicated {} layers of {}x{} (k={}) in {:.1}ms \
         [engine={} mb={} slice={} threads={}]",
        layers.len(),
        model.neurons,
        model.layers,
        model.k,
        t.elapsed().as_secs_f64() * 1e3,
        spec.engine,
        spec.minibatch,
        spec.slice,
        spec.threads
    );
    Ok(Replica { rank, model, spec, prune, layers: Arc::new(layers), bias })
}

/// Run all layers over one scattered shard; the exact same code path as
/// an in-process worker thread.
fn run_shard(
    replica: &Replica,
    start: usize,
    features: Vec<f32>,
) -> Result<super::transport::ShardResult> {
    let n = replica.model.neurons;
    if n == 0 {
        bail!("replica has zero-width model");
    }
    if features.len() % n != 0 {
        bail!("shard of {} values is not a multiple of neurons={n}", features.len());
    }
    let count = features.len() / n;
    let task = WorkerTask {
        id: replica.rank,
        backend: BackendKind::Native {
            threads: replica.spec.threads,
            minibatch: replica.spec.minibatch,
            engine: replica.spec.engine,
            slice: replica.spec.slice,
        },
        neurons: n,
        k: replica.model.k,
        nlayers: replica.model.layers,
        bias: replica.bias.clone(),
        prune: replica.prune,
        features,
        global_start: start,
        weights: WeightSource::Memory(replica.layers.clone()),
    };
    let t = Instant::now();
    let out = crate::coordinator::worker::run_worker(task)?;
    Ok(super::transport::ShardResult {
        rank: replica.rank,
        start,
        count,
        categories: out.categories,
        activations: out.final_y,
        live_per_layer: out.metrics.live_per_layer,
        layer_secs: out.metrics.layer_secs,
        edges_traversed: out.metrics.edges_traversed,
        secs: t.elapsed().as_secs_f64(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Dataset;
    use crate::engine::EngineKind;
    use crate::util::config::RuntimeConfig;

    fn small_cfg() -> RuntimeConfig {
        RuntimeConfig { neurons: 64, layers: 5, k: 4, batch: 12, ..Default::default() }
    }

    fn spec() -> NativeSpec {
        NativeSpec { engine: EngineKind::Ell, minibatch: 12, slice: 32, threads: 1 }
    }

    #[test]
    fn replica_weights_match_dataset_generation() {
        let cfg = small_cfg();
        let ds = Dataset::generate(&cfg).unwrap();
        let (layers, bias) = build_replica_weights(&ModelSpec::from_config(&cfg)).unwrap();
        assert_eq!(layers, ds.layers, "replicated weights must be bit-identical");
        assert_eq!(bias, ds.bias);
    }

    #[test]
    fn shard_runs_match_truth() {
        let cfg = small_cfg();
        let ds = Dataset::generate(&cfg).unwrap();
        let model = ModelSpec::from_config(&cfg);
        let replica = load_replica(0, model, spec(), true).unwrap();
        let out = run_shard(&replica, 0, ds.features.clone()).unwrap();
        assert_eq!(out.categories, ds.truth_categories);
        assert_eq!(out.count, cfg.batch);
        assert_eq!(out.live_per_layer.len(), cfg.layers);
        assert_eq!(out.activations.len(), out.categories.len() * cfg.neurons);
    }

    #[test]
    fn shard_offsets_are_global() {
        let cfg = small_cfg();
        let ds = Dataset::generate(&cfg).unwrap();
        let replica = load_replica(1, ModelSpec::from_config(&cfg), spec(), true).unwrap();
        let out = run_shard(&replica, 100, ds.features.clone()).unwrap();
        let expect: Vec<usize> = ds.truth_categories.iter().map(|c| c + 100).collect();
        assert_eq!(out.categories, expect);
        assert_eq!(out.rank, 1);
    }

    #[test]
    fn ragged_shard_rejected() {
        let cfg = small_cfg();
        let replica = load_replica(0, ModelSpec::from_config(&cfg), spec(), true).unwrap();
        assert!(run_shard(&replica, 0, vec![0.0; 63]).is_err());
    }

    #[test]
    fn empty_shard_is_fine() {
        let cfg = small_cfg();
        let replica = load_replica(0, ModelSpec::from_config(&cfg), spec(), true).unwrap();
        let out = run_shard(&replica, 0, vec![]).unwrap();
        assert!(out.categories.is_empty());
        assert_eq!(out.count, 0);
    }

    #[test]
    fn bad_topology_fails_load() {
        let mut model = ModelSpec::from_config(&small_cfg());
        model.topology = "mesh".into();
        assert!(load_replica(0, model, spec(), true).is_err());
    }
}
