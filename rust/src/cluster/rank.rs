//! A worker rank: one OS process holding its share of the weights and
//! executing whatever rank 0 scatters to it.
//!
//! Two partitioning schemes share the process:
//!
//! * **Feature partitioning** (paper §IV.C, the default): the rank
//!   holds a *full* weight replica and runs the whole layer loop over
//!   its static feature shard (`shard` / `shard-begin` ops).
//! * **Weight partitioning** (protocol v4): the `load` carries a
//!   `(start, count)` row range and the rank keeps only that contiguous
//!   row slice of every layer. Each `exchange` op then runs **one**
//!   layer of the slice over the full live panel and answers with the
//!   partial `[rows, count]` post-ReLU panel; the coordinator
//!   reassembles the next layer's input (the all-to-all
//!   boundary-activation exchange).
//!
//! The process is started as `spdnn cluster-worker --listen HOST:PORT`
//! (port 0 picks a free port), announces its bound address on stdout as
//! `SPDNN-CLUSTER-WORKER <addr>` for the launcher to scrape, then serves
//! coordinator connections sequentially until a `shutdown` op arrives.
//!
//! The compute path is exactly the in-process one: a `load` op rebuilds
//! the weight set deterministically (same RadixNet topology + seed as
//! rank 0, so replication costs generation time, not network transfer)
//! and resolves the v2 engine **once** — for the sliced engine that
//! includes pre-slicing the resident weights, so shard ops pay zero
//! setup. Every `shard` (or pipelined `shard-begin`/`shard-chunk`
//! stream) then runs `coordinator::worker::run_resident_panel` over the
//! borrowed bias and features — which is what makes cluster output
//! bit-identical to single-process inference.
//!
//! Frame hygiene: every read is capped ([`CONTROL_FRAME_CAP`] before a
//! model is loaded, [`data_frame_cap`] after), so a misbehaving or
//! malicious peer cannot OOM the rank with one giant line; it gets a
//! protocol-error reply and its connection is dropped, while the
//! process stays up for the next coordinator.

use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::worker::{run_resident_panel, NativeExec, PanelTask};
use crate::coordinator::NativeSpec;
use crate::formats::EllMatrix;
use crate::obs::flight;
use crate::obs::metrics as om;
use crate::obs::trace::{now_unix_micros, SpanRecord, TraceId};
use crate::radixnet::{RadixNet, Topology};
use crate::{log_info, log_warn};

use super::transport::{
    data_frame_cap, read_request, write_reply, ClusterReply, ClusterRequest, ModelSpec,
    ReadOutcome, ShardResult, WireFormat, CLUSTER_PROTOCOL_VERSION, CONTROL_FRAME_CAP,
};

/// First stdout line of a worker: `SPDNN-CLUSTER-WORKER <addr>`.
pub const READY_PREFIX: &str = "SPDNN-CLUSTER-WORKER";

/// The rank's resident weights plus the engine a `load` op resolved.
struct Replica {
    rank: usize,
    model: ModelSpec,
    prune: bool,
    /// `None`: full replica (feature partitioning). `Some((start,
    /// count))`: `layers`/`bias` hold only that row slice of every
    /// layer (weight partitioning).
    shard: Option<(usize, usize)>,
    layers: Arc<Vec<EllMatrix>>,
    /// Shared bias panel — borrowed by every shard op, never cloned.
    bias: Arc<Vec<f32>>,
    /// Engine built once per load; owns the pre-sliced weight cache.
    exec: NativeExec,
}

/// Build the full weight set for `model` (deterministic replication:
/// every rank generates identical layers from the shared recipe).
pub fn build_replica_weights(model: &ModelSpec) -> Result<(Vec<EllMatrix>, Vec<f32>)> {
    let topo = Topology::parse(&model.topology)?;
    let net = RadixNet::new(model.neurons, model.layers, model.k, topo, model.seed)?;
    let layers: Vec<EllMatrix> = (0..model.layers).map(|l| net.layer_ell(l)).collect();
    let bias = vec![model.bias as f32; model.neurons];
    Ok((layers, bias))
}

enum ConnOutcome {
    /// Peer disconnected (or broke protocol); go back to accept.
    Disconnected,
    /// A shutdown op was acknowledged; the process should exit.
    Shutdown,
}

/// Serve one worker rank until a `shutdown` op arrives. Announces the
/// bound address on stdout first (the launcher's readiness handshake).
pub fn serve_rank(listener: TcpListener) -> Result<()> {
    let addr = listener.local_addr().context("resolving bound address")?;
    println!("{READY_PREFIX} {addr}");
    std::io::stdout().flush().ok();

    // Keep a flight record for the life of the process, and register
    // the rank's counter families eagerly so a metrics pull arriving
    // before any traffic still answers a non-empty exposition.
    flight::enable();
    rank_counters();

    let mut replica: Option<Replica> = None;
    loop {
        let (stream, peer) = listener.accept().context("accepting coordinator connection")?;
        log_info!("cluster worker: coordinator connected from {peer}");
        match serve_connection(stream, &mut replica) {
            Ok(ConnOutcome::Shutdown) => {
                log_info!("cluster worker: shutdown acknowledged, exiting");
                return Ok(());
            }
            Ok(ConnOutcome::Disconnected) => {}
            Err(e) => log_warn!("cluster worker: connection error: {e:#}"),
        }
    }
}

/// The worker-side counter families. Fetching is a registry lookup, so
/// the hot paths call this per operation rather than caching handles.
fn rank_counters() -> (om::Counter, om::Counter, om::Counter) {
    (
        om::counter("spdnn_rank_shards_total", "Shard and shard-chunk panels computed"),
        om::counter("spdnn_rank_exchanges_total", "Weight-sharded exchange layers computed"),
        om::counter("spdnn_rank_edges_total", "Edges traversed by this rank"),
    )
}

fn send(w: &mut impl Write, reply: &ClusterReply, wire: WireFormat) -> Result<()> {
    write_reply(w, reply, wire)?;
    w.flush().context("flushing reply")?;
    Ok(())
}

fn frame_cap(replica: Option<&Replica>) -> usize {
    replica.map(|r| data_frame_cap(r.model.neurons)).unwrap_or(CONTROL_FRAME_CAP)
}

fn serve_connection(stream: TcpStream, replica: &mut Option<Replica>) -> Result<ConnOutcome> {
    stream.set_nodelay(true).ok();
    let mut writer = BufWriter::new(stream.try_clone().context("cloning connection")?);
    let mut reader = BufReader::new(stream);
    loop {
        let cap = frame_cap(replica.as_ref());
        let (req, wire) = match read_request(&mut reader, cap) {
            Ok(ReadOutcome::Eof) => return Ok(ConnOutcome::Disconnected),
            Ok(ReadOutcome::Msg(req, wire)) => (req, wire),
            Ok(ReadOutcome::Invalid(e, wire)) => {
                // The message was fully consumed (complete line or
                // frame), so the stream is still in sync: answer with
                // an error and keep serving, exactly like protocol v1.
                let reply = ClusterReply::Error { message: format!("{e:#}") };
                send(&mut writer, &reply, wire)?;
                continue;
            }
            Err(e) => {
                // The stream cannot be resynced after a framing error
                // (an oversized line, bad magic, a truncated frame):
                // answer with a protocol error — instead of aborting
                // the process or buffering a hostile line without
                // bound — and drop the connection. The rank stays up
                // for the next accept.
                flight::record(flight::FRAME_ERROR, || format!("dropping connection: {e:#}"));
                let reply = ClusterReply::Error { message: format!("protocol error: {e:#}") };
                let _ = send(&mut writer, &reply, WireFormat::Json);
                return Ok(ConnOutcome::Disconnected);
            }
        };
        let (reply, reply_wire, outcome) = match req {
            ClusterRequest::Ping => {
                (ClusterReply::Pong { version: CLUSTER_PROTOCOL_VERSION }, wire, None)
            }
            ClusterRequest::Hello { wire: proposed } => (
                // Echo the proposed wire: both encodings are understood,
                // the handshake exists so version/wire skew fails loudly
                // at connect time.
                ClusterReply::Hello { version: CLUSTER_PROTOCOL_VERSION, wire: proposed },
                wire,
                None,
            ),
            ClusterRequest::Load { rank, model, spec, prune, shard } => {
                match load_replica(rank, model, spec, prune, shard) {
                    Ok(r) => {
                        // The load op is where this process learns its
                        // fleet identity; stamp stderr with it.
                        crate::util::logger::set_role(&format!("rank {}", r.rank));
                        let reply = ClusterReply::Loaded {
                            rank: r.rank,
                            neurons: r.model.neurons,
                            layers: r.model.layers,
                        };
                        *replica = Some(r);
                        (reply, wire, None)
                    }
                    Err(e) => (ClusterReply::Error { message: format!("{e:#}") }, wire, None),
                }
            }
            ClusterRequest::Shard { start, features, trace } => match replica.as_ref() {
                Some(r) => match run_shard(r, start, &features, trace) {
                    Ok(result) => (ClusterReply::Result(Box::new(result)), wire, None),
                    Err(e) => (ClusterReply::Error { message: format!("{e:#}") }, wire, None),
                },
                None => (
                    ClusterReply::Error {
                        message: "no model loaded on this rank (send a load op first)".into(),
                    },
                    wire,
                    None,
                ),
            },
            ClusterRequest::ShardBegin { start, rows, chunks, trace } => {
                let got =
                    receive_chunked(&mut reader, replica.as_ref(), start, rows, chunks, cap, trace);
                match got {
                    // The result goes back in the encoding the chunk
                    // frames arrived in (shard-begin itself is always a
                    // JSON control line, so its wire would wrongly
                    // downgrade a binary gather).
                    Ok((result, data_wire)) => {
                        (ClusterReply::Result(Box::new(result)), data_wire, None)
                    }
                    Err(e) => {
                        // Mid-stream failure: unread chunks may still be
                        // in flight, so the stream is unrecoverable —
                        // reply, then drop the connection.
                        let reply = ClusterReply::Error { message: format!("{e:#}") };
                        let _ = send(&mut writer, &reply, wire);
                        return Ok(ConnOutcome::Disconnected);
                    }
                }
            }
            ClusterRequest::Exchange { layer, features, trace: _ } => match replica.as_ref() {
                Some(r) => match run_exchange(r, layer, &features) {
                    Ok(reply) => (reply, wire, None),
                    Err(e) => (ClusterReply::Error { message: format!("{e:#}") }, wire, None),
                },
                None => (
                    ClusterReply::Error {
                        message: "no model loaded on this rank (send a load op first)".into(),
                    },
                    wire,
                    None,
                ),
            },
            ClusterRequest::ShardChunk { index, .. } => (
                ClusterReply::Error {
                    message: format!(
                        "shard-chunk {index} outside an active shard stream \
                         (send shard-begin first)"
                    ),
                },
                wire,
                None,
            ),
            ClusterRequest::Metrics => {
                // The telemetry pull: this rank's whole registry plus
                // its recent flight events, for the coordinator to
                // federate (rank-relabeling happens there).
                (
                    ClusterReply::Metrics { text: om::render(), events: flight::snapshot() },
                    wire,
                    None,
                )
            }
            ClusterRequest::Shutdown => (ClusterReply::Bye, wire, Some(ConnOutcome::Shutdown)),
        };
        send(&mut writer, &reply, reply_wire)?;
        if let Some(outcome) = outcome {
            return Ok(outcome);
        }
    }
}

/// Drain one pipelined scatter (`chunks` shard-chunk messages after a
/// shard-begin), computing each sub-panel the moment it arrives — the
/// §III.B overlap: while chunk *i* runs the layer loop here, chunk
/// *i+1* is still moving through the socket. The merged result is
/// bit-identical to a whole-shard run because feature rows are
/// independent through every layer (same argument that makes the
/// rank-level scatter exact). Returns the merged result plus the wire
/// the chunk frames arrived in, which is the encoding the result reply
/// must use.
fn receive_chunked(
    reader: &mut impl BufRead,
    replica: Option<&Replica>,
    start: usize,
    rows: usize,
    chunks: usize,
    cap: usize,
    trace: TraceId,
) -> Result<(ShardResult, WireFormat)> {
    let r =
        replica.ok_or_else(|| anyhow!("no model loaded on this rank (send a load op first)"))?;
    let nlayers = r.model.layers;
    let ts0 = now_unix_micros();
    let t = Instant::now();
    let mut categories = Vec::new();
    let mut activations = Vec::new();
    let mut live_per_layer = vec![0usize; nlayers];
    let mut layer_secs = vec![0f64; nlayers];
    let mut edges = 0u64;
    let mut spans: Vec<SpanRecord> = Vec::new();
    let mut row = start;
    // An empty stream (0 chunks) has no data frames to take the
    // encoding from; JSON is always understood by the peer.
    let mut data_wire = WireFormat::Json;
    for index in 0..chunks {
        let (req, wire) = match read_request(reader, cap)? {
            ReadOutcome::Msg(req, wire) => (req, wire),
            ReadOutcome::Eof => {
                bail!("peer closed mid shard stream (chunk {index}/{chunks})")
            }
            ReadOutcome::Invalid(e, _) => {
                bail!("invalid message mid shard stream (chunk {index}/{chunks}): {e:#}")
            }
        };
        data_wire = wire;
        let (got_index, chunk_start, features) = match req {
            ClusterRequest::ShardChunk { index, start, features } => (index, start, features),
            other => bail!("expected shard-chunk {index}, got a {} op", other.op()),
        };
        if got_index != index {
            bail!("shard chunk out of order: got {got_index}, expected {index}");
        }
        if chunk_start != row {
            bail!("shard chunk {index} starts at row {chunk_start}, expected {row}");
        }
        let out = run_shard(r, chunk_start, &features, trace)?;
        row += out.count;
        if row > start + rows {
            bail!("shard chunks overflow the announced {rows} rows");
        }
        categories.extend(out.categories);
        activations.extend(out.activations);
        for (acc, v) in live_per_layer.iter_mut().zip(&out.live_per_layer) {
            *acc += v;
        }
        for (acc, v) in layer_secs.iter_mut().zip(&out.layer_secs) {
            *acc += v;
        }
        edges += out.edges_traversed;
        spans.extend(out.spans);
    }
    if row != start + rows {
        bail!("shard chunks cover {} rows, shard-begin announced {rows}", row - start);
    }
    let secs = t.elapsed().as_secs_f64();
    if trace.is_some() {
        // The stream span wraps every per-chunk compute span: its gaps
        // are the §III.B transfer/compute overlap made visible.
        spans.push(SpanRecord {
            name: "rank-stream".into(),
            ts_us: ts0,
            dur_us: (secs * 1e6) as u64,
            trace,
            lane: r.rank as u32 + 1,
            tid: 0,
            args: vec![
                ("rank".into(), r.rank.to_string()),
                ("chunks".into(), chunks.to_string()),
            ],
        });
    }
    Ok((
        ShardResult {
            rank: r.rank,
            start,
            count: rows,
            categories,
            activations,
            live_per_layer,
            layer_secs,
            edges_traversed: edges,
            secs,
            trace,
            spans,
        },
        data_wire,
    ))
}

fn load_replica(
    rank: usize,
    model: ModelSpec,
    spec: NativeSpec,
    prune: bool,
    shard: Option<(usize, usize)>,
) -> Result<Replica> {
    let t = Instant::now();
    let (mut layers, mut bias) = build_replica_weights(&model)?;
    if let Some((start, count)) = shard {
        if start.checked_add(count).is_none_or(|end| end > model.neurons) {
            bail!(
                "weight shard rows {start}..{start}+{count} exceed the model's {} neurons",
                model.neurons
            );
        }
        // Keep only this rank's contiguous row slice of every layer.
        // Row slicing preserves each row's entry order, which is what
        // keeps the reassembled cluster output bit-identical to a
        // single-process run.
        layers = layers.iter().map(|w| w.row_slice(start, count)).collect();
        bias = bias[start..start + count].to_vec();
    }
    let exec =
        NativeExec::build(spec.threads, spec.minibatch, spec.engine, spec.slice, Some(&layers))
            .context("cluster rank engine init")?;
    let held = match shard {
        None => "replicated".to_string(),
        Some((start, count)) => format!("sharded rows {start}..{}", start + count),
    };
    log_info!(
        "cluster worker rank {rank}: {held} {} layers of {}x{} (k={}) in {:.1}ms \
         [engine={} mb={} slice={} threads={}]",
        layers.len(),
        model.neurons,
        model.layers,
        model.k,
        t.elapsed().as_secs_f64() * 1e3,
        spec.engine,
        spec.minibatch,
        spec.slice,
        spec.threads
    );
    Ok(Replica {
        rank,
        model,
        prune,
        shard,
        layers: Arc::new(layers),
        bias: Arc::new(bias),
        exec,
    })
}

/// Run all layers over one scattered panel; the exact same code path as
/// an in-process worker thread, minus any per-op copies: the prebuilt
/// engine, the shared bias and the feature slice are all borrowed.
///
/// A non-NONE `trace` turns the per-layer timings the result already
/// carries into spans on the rank's own lane (`rank + 1`), so the
/// coordinator can stitch one end-to-end trace. Ranks keep no global
/// recorder state: the spans live only in the result.
fn run_shard(
    replica: &Replica,
    start: usize,
    features: &[f32],
    trace: TraceId,
) -> Result<ShardResult> {
    if replica.shard.is_some() {
        bail!("this rank holds a weight shard; feature-partitioned ops need a full replica");
    }
    let n = replica.model.neurons;
    if n == 0 {
        bail!("replica has zero-width model");
    }
    if features.len() % n != 0 {
        bail!("shard of {} values is not a multiple of neurons={n}", features.len());
    }
    let count = features.len() / n;
    let ts0 = now_unix_micros();
    let t = Instant::now();
    let out = run_resident_panel(
        &replica.exec,
        &replica.layers,
        &PanelTask {
            id: replica.rank,
            neurons: n,
            k: replica.model.k,
            nlayers: replica.model.layers,
            bias: &replica.bias,
            prune: replica.prune,
            features,
            global_start: start,
        },
    )?;
    let secs = t.elapsed().as_secs_f64();
    let (m_shards, _, m_edges) = rank_counters();
    m_shards.inc();
    m_edges.add(out.metrics.edges_traversed);
    let mut spans = Vec::new();
    if trace.is_some() {
        let lane = replica.rank as u32 + 1;
        let rank_arg = ("rank".to_string(), replica.rank.to_string());
        // Per-layer spans laid back-to-back from the shard's start: the
        // layer loop runs them sequentially, so cumulative offsets of
        // the measured durations reconstruct the real timeline.
        let mut off = 0u64;
        for (l, &s) in out.metrics.layer_secs.iter().enumerate() {
            let dur = (s * 1e6) as u64;
            spans.push(SpanRecord {
                name: "layer".into(),
                ts_us: ts0 + off,
                dur_us: dur,
                trace,
                lane,
                tid: 0,
                args: vec![("layer".into(), l.to_string()), rank_arg.clone()],
            });
            off += dur;
        }
        spans.push(SpanRecord {
            name: "rank-compute".into(),
            ts_us: ts0,
            dur_us: (secs * 1e6) as u64,
            trace,
            lane,
            tid: 0,
            args: vec![rank_arg, ("rows".into(), count.to_string())],
        });
    }
    Ok(ShardResult {
        rank: replica.rank,
        start,
        count,
        categories: out.categories,
        activations: out.final_y,
        live_per_layer: out.metrics.live_per_layer,
        layer_secs: out.metrics.layer_secs,
        edges_traversed: out.metrics.edges_traversed,
        secs,
        trace,
        spans,
    })
}

/// Weight-sharded mode: run **one** layer of this rank's row shard over
/// the full live panel `[rows, neurons]`, answering the partial
/// `[rows, count]` post-ReLU panel. No pruning happens here — only the
/// coordinator sees the reassembled full rows, so only it can decide
/// which features died.
fn run_exchange(replica: &Replica, layer: usize, features: &[f32]) -> Result<ClusterReply> {
    let (_, count) = replica.shard.ok_or_else(|| {
        anyhow!("this rank holds a full replica; exchange ops need a weight-sharded load")
    })?;
    let n = replica.model.neurons;
    if layer >= replica.model.layers {
        bail!("layer {layer} out of range (model has {} layers)", replica.model.layers);
    }
    if features.len() % n.max(1) != 0 {
        bail!("exchange panel of {} values is not a multiple of neurons={n}", features.len());
    }
    let rows = features.len() / n.max(1);
    let t = Instant::now();
    let mut values = vec![0.0f32; rows * count];
    replica.exec.layer(layer, &replica.layers[layer], &replica.bias, features, &mut values)?;
    let secs = t.elapsed().as_secs_f64();
    rank_counters().1.inc();
    Ok(ClusterReply::Partial { rank: replica.rank, layer, count, secs, values })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Dataset;
    use crate::engine::EngineKind;
    use crate::util::config::RuntimeConfig;

    fn small_cfg() -> RuntimeConfig {
        RuntimeConfig { neurons: 64, layers: 5, k: 4, batch: 12, ..Default::default() }
    }

    fn spec() -> NativeSpec {
        NativeSpec { engine: EngineKind::Ell, minibatch: 12, slice: 32, threads: 1 }
    }

    #[test]
    fn replica_weights_match_dataset_generation() {
        let cfg = small_cfg();
        let ds = Dataset::generate(&cfg).unwrap();
        let (layers, bias) = build_replica_weights(&ModelSpec::from_config(&cfg)).unwrap();
        assert_eq!(layers, ds.layers, "replicated weights must be bit-identical");
        assert_eq!(bias, ds.bias);
    }

    #[test]
    fn shard_runs_match_truth() {
        let cfg = small_cfg();
        let ds = Dataset::generate(&cfg).unwrap();
        let model = ModelSpec::from_config(&cfg);
        let replica = load_replica(0, model, spec(), true, None).unwrap();
        let out = run_shard(&replica, 0, &ds.features, TraceId::NONE).unwrap();
        assert_eq!(out.categories, ds.truth_categories);
        assert_eq!(out.count, cfg.batch);
        assert_eq!(out.live_per_layer.len(), cfg.layers);
        assert_eq!(out.activations.len(), out.categories.len() * cfg.neurons);
    }

    #[test]
    fn sliced_replica_preslices_once_and_matches_truth() {
        let cfg = small_cfg();
        let ds = Dataset::generate(&cfg).unwrap();
        let sliced =
            NativeSpec { engine: EngineKind::Sliced, minibatch: 12, slice: 16, threads: 1 };
        let replica = load_replica(0, ModelSpec::from_config(&cfg), sliced, true, None).unwrap();
        // Two shard ops against the same prebuilt engine: identical output.
        let a = run_shard(&replica, 0, &ds.features, TraceId::NONE).unwrap();
        let b = run_shard(&replica, 0, &ds.features, TraceId::NONE).unwrap();
        assert_eq!(a.categories, ds.truth_categories);
        assert_eq!(a.categories, b.categories);
        assert_eq!(a.activations, b.activations);
    }

    #[test]
    fn shard_offsets_are_global() {
        let cfg = small_cfg();
        let ds = Dataset::generate(&cfg).unwrap();
        let replica = load_replica(1, ModelSpec::from_config(&cfg), spec(), true, None).unwrap();
        let out = run_shard(&replica, 100, &ds.features, TraceId::NONE).unwrap();
        let expect: Vec<usize> = ds.truth_categories.iter().map(|c| c + 100).collect();
        assert_eq!(out.categories, expect);
        assert_eq!(out.rank, 1);
    }

    #[test]
    fn ragged_shard_rejected() {
        let cfg = small_cfg();
        let replica = load_replica(0, ModelSpec::from_config(&cfg), spec(), true, None).unwrap();
        assert!(run_shard(&replica, 0, &[0.0; 63], TraceId::NONE).is_err());
    }

    #[test]
    fn empty_shard_is_fine() {
        let cfg = small_cfg();
        let replica = load_replica(0, ModelSpec::from_config(&cfg), spec(), true, None).unwrap();
        let out = run_shard(&replica, 0, &[], TraceId::NONE).unwrap();
        assert!(out.categories.is_empty());
        assert_eq!(out.count, 0);
    }

    #[test]
    fn traced_shard_returns_rank_spans() {
        let cfg = small_cfg();
        let ds = Dataset::generate(&cfg).unwrap();
        let replica = load_replica(1, ModelSpec::from_config(&cfg), spec(), true, None).unwrap();
        let trace = TraceId(0xFEED);
        let out = run_shard(&replica, 0, &ds.features, trace).unwrap();
        assert_eq!(out.trace, trace);
        // One span per layer plus the whole-shard compute span, all on
        // the rank's own lane (rank + 1) carrying the request trace.
        assert_eq!(out.spans.len(), cfg.layers + 1);
        assert!(out.spans.iter().all(|s| s.trace == trace && s.lane == 2));
        assert!(out.spans.iter().any(|s| s.name == "rank-compute"));
        assert_eq!(out.spans.iter().filter(|s| s.name == "layer").count(), cfg.layers);
        // Untraced shards stay span-free (the exact v2 result shape).
        let out = run_shard(&replica, 0, &ds.features, TraceId::NONE).unwrap();
        assert!(out.spans.is_empty());
        assert!(out.trace.is_none());
    }

    #[test]
    fn chunked_receive_matches_whole_shard_bit_exactly() {
        let cfg = small_cfg();
        let ds = Dataset::generate(&cfg).unwrap();
        let replica = load_replica(0, ModelSpec::from_config(&cfg), spec(), true, None).unwrap();
        let whole = run_shard(&replica, 0, &ds.features, TraceId::NONE).unwrap();

        // Feed the chunked receiver from an in-memory stream: 12 rows
        // as chunks of 5 + 5 + 2.
        let n = cfg.neurons;
        let mut wire = Vec::new();
        for (i, chunk) in ds.features.chunks(5 * n).enumerate() {
            super::super::transport::write_shard_chunk(
                &mut wire,
                WireFormat::Bin,
                i,
                i * 5,
                chunk,
            )
            .unwrap();
        }
        let (chunked, data_wire) = receive_chunked(
            &mut &wire[..],
            Some(&replica),
            0,
            cfg.batch,
            3,
            CONTROL_FRAME_CAP,
            TraceId::NONE,
        )
        .unwrap();
        // Binary chunk frames => the result reply must stay binary too.
        assert_eq!(data_wire, WireFormat::Bin);
        assert_eq!(chunked.categories, whole.categories);
        assert_eq!(chunked.count, whole.count);
        assert_eq!(chunked.live_per_layer, whole.live_per_layer);
        assert_eq!(chunked.edges_traversed, whole.edges_traversed);
        assert_eq!(chunked.activations.len(), whole.activations.len());
        for (a, b) in chunked.activations.iter().zip(&whole.activations) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn chunked_receive_rejects_gaps_and_short_streams() {
        let cfg = small_cfg();
        let ds = Dataset::generate(&cfg).unwrap();
        let replica = load_replica(0, ModelSpec::from_config(&cfg), spec(), true, None).unwrap();
        let n = cfg.neurons;

        // Out-of-order chunk index.
        let mut wire = Vec::new();
        super::super::transport::write_shard_chunk(
            &mut wire,
            WireFormat::Bin,
            1,
            0,
            &ds.features[..5 * n],
        )
        .unwrap();
        let err = receive_chunked(
            &mut &wire[..],
            Some(&replica),
            0,
            12,
            3,
            CONTROL_FRAME_CAP,
            TraceId::NONE,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("out of order"), "unexpected error: {err}");

        // Stream ends before the announced chunk count.
        let mut wire = Vec::new();
        super::super::transport::write_shard_chunk(
            &mut wire,
            WireFormat::Bin,
            0,
            0,
            &ds.features[..5 * n],
        )
        .unwrap();
        let err = receive_chunked(
            &mut &wire[..],
            Some(&replica),
            0,
            12,
            3,
            CONTROL_FRAME_CAP,
            TraceId::NONE,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("closed mid shard stream"), "unexpected error: {err}");
    }

    #[test]
    fn bad_topology_fails_load() {
        let mut model = ModelSpec::from_config(&small_cfg());
        model.topology = "mesh".into();
        assert!(load_replica(0, model, spec(), true, None).is_err());
    }

    #[test]
    fn sharded_exchanges_reassemble_the_full_layer_bit_exactly() {
        // Two weight-sharded ranks (uneven 43+21 split of 64 rows),
        // each answering one exchange per layer; stitching the partial
        // panels together must equal the full replica's layer output.
        let cfg = small_cfg();
        let ds = Dataset::generate(&cfg).unwrap();
        let model = ModelSpec::from_config(&cfg);
        let full = load_replica(0, model.clone(), spec(), true, None).unwrap();
        let parts = [(0usize, 43usize), (43, 21)];
        let replicas: Vec<Replica> = parts
            .iter()
            .enumerate()
            .map(|(r, &(s, c))| {
                load_replica(r, model.clone(), spec(), true, Some((s, c))).unwrap()
            })
            .collect();

        let n = cfg.neurons;
        let rows = cfg.batch;
        let mut y = ds.features.clone();
        for layer in 0..cfg.layers {
            // Full-replica truth for this layer.
            let mut want = vec![0.0f32; rows * n];
            full.exec.layer(layer, &full.layers[layer], &full.bias, &y, &mut want).unwrap();
            // Weight-sharded: stitch the two partial panels.
            let mut got = vec![0.0f32; rows * n];
            for (replica, &(s, c)) in replicas.iter().zip(&parts) {
                let reply = run_exchange(replica, layer, &y).unwrap();
                let ClusterReply::Partial { rank, layer: l, count, values, .. } = reply else {
                    panic!("expected a partial reply");
                };
                assert_eq!(rank, replica.rank);
                assert_eq!(l, layer);
                assert_eq!(count, c);
                assert_eq!(values.len(), rows * c);
                for f in 0..rows {
                    got[f * n + s..f * n + s + c].copy_from_slice(&values[f * c..(f + 1) * c]);
                }
            }
            for (a, b) in got.iter().zip(&want) {
                assert_eq!(a.to_bits(), b.to_bits(), "layer {layer}");
            }
            y = want;
        }
    }

    #[test]
    fn sharded_replica_rejects_feature_ops_and_vice_versa() {
        let cfg = small_cfg();
        let ds = Dataset::generate(&cfg).unwrap();
        let model = ModelSpec::from_config(&cfg);
        let sharded = load_replica(0, model.clone(), spec(), true, Some((0, 32))).unwrap();
        let err = run_shard(&sharded, 0, &ds.features, TraceId::NONE).unwrap_err().to_string();
        assert!(err.contains("weight shard"), "unexpected error: {err}");

        let full = load_replica(0, model.clone(), spec(), true, None).unwrap();
        let err = run_exchange(&full, 0, &ds.features).unwrap_err().to_string();
        assert!(err.contains("full replica"), "unexpected error: {err}");

        // Out-of-range layers and shard ranges fail cleanly too.
        let err = run_exchange(&sharded, cfg.layers, &ds.features).unwrap_err().to_string();
        assert!(err.contains("out of range"), "unexpected error: {err}");
        assert!(load_replica(0, model, spec(), true, Some((60, 8))).is_err());
    }
}
