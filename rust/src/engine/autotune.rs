//! Per-network autotuner for the native engines.
//!
//! The paper fixes MINIBATCH=12 and warp-granularity slicing for the
//! V100; the right point shifts with network shape and host (Gale et
//! al.: layout-aware traversal plus per-problem tuning is where sparse
//! kernels win). This module sweeps `(engine, minibatch, slice
//! granularity, threads)` over a short calibration run on a synthetic
//! layer of the requested shape, picks the fastest configuration by
//! edges/second, and caches the decision in a tuning table keyed by
//! `(neurons, k, layers)`. The table serializes to JSON
//! (`spdnn-tune-v1`) so a deployment can persist tuning across runs
//! (`--tune-cache`).

use std::collections::BTreeMap;
use std::path::Path;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::formats::convert::ell_to_csr;
use crate::formats::SlicedEll;
use crate::radixnet::{RadixNet, Topology};
use crate::util::config::RuntimeConfig;
use crate::util::json::Json;
use crate::util::prng::Xoshiro256;
use crate::util::threadpool::ThreadPool;

use super::{CsrEngine, EllEngine, EngineKind, SlicedEllEngine};

/// Schema tag of the serialized tuning table.
pub const TUNE_SCHEMA: &str = "spdnn-tune-v1";

/// Identity of the machine a tuning table was calibrated on. A table
/// tuned on one host is meaningless on another (different core count,
/// pool size, cache hierarchy); persisted tables carry this fingerprint
/// so `--tune-cache` can warn-and-retune instead of silently reusing a
/// foreign table.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HostFingerprint {
    pub hostname: String,
    /// `std::thread::available_parallelism` at calibration time.
    pub cpus: usize,
    /// `util::threadpool::ThreadPool::global().size()` at calibration.
    pub pool: usize,
}

impl HostFingerprint {
    /// The fingerprint of the machine this process runs on.
    pub fn current() -> HostFingerprint {
        HostFingerprint {
            hostname: read_hostname(),
            cpus: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            pool: ThreadPool::global().size(),
        }
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("hostname", Json::Str(self.hostname.clone())),
            ("cpus", Json::Int(self.cpus as i64)),
            ("pool", Json::Int(self.pool as i64)),
        ])
    }

    fn from_json(j: &Json) -> Result<HostFingerprint> {
        Ok(HostFingerprint {
            hostname: j.req_str("hostname")?.to_string(),
            cpus: j.req_usize("cpus")?,
            pool: j.req_usize("pool")?,
        })
    }
}

/// Best-effort hostname without external crates. Kernel sources come
/// first — they are stable across shells on the same machine, whereas
/// `$HOSTNAME` is exported by some shells and absent in others (cron,
/// CI), which would make the same host fingerprint two ways. Non-Linux
/// hosts (no /proc, usually no /etc/hostname) fall back to one
/// `hostname` exec before giving up.
fn read_hostname() -> String {
    for path in ["/proc/sys/kernel/hostname", "/etc/hostname"] {
        if let Ok(h) = std::fs::read_to_string(path) {
            if !h.trim().is_empty() {
                return h.trim().to_string();
            }
        }
    }
    if let Ok(h) = std::env::var("HOSTNAME") {
        if !h.trim().is_empty() {
            return h.trim().to_string();
        }
    }
    if let Ok(out) = std::process::Command::new("hostname").output() {
        let h = String::from_utf8_lossy(&out.stdout);
        if !h.trim().is_empty() {
            return h.trim().to_string();
        }
    }
    "unknown-host".to_string()
}

/// Network shape a tuning decision applies to.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct TuneKey {
    pub neurons: usize,
    pub k: usize,
    pub layers: usize,
}

/// One tuning decision: the engine and its knobs, plus the calibration
/// throughput that backed the choice.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TunedConfig {
    pub engine: EngineKind,
    pub minibatch: usize,
    /// Slice granularity (sliced engine only; 0 for csr/ell).
    pub slice: usize,
    pub threads: usize,
    /// Calibration throughput (edges/second) of this configuration.
    pub edges_per_sec: f64,
}

/// The autotuner: a calibration sweep plus the cached decision table.
pub struct Autotuner {
    table: BTreeMap<TuneKey, TunedConfig>,
    /// Wall-clock budget of one calibration sweep (seconds). Once at
    /// least one candidate is measured the sweep stops on exhaustion.
    pub budget_secs: f64,
    /// Timed repetitions per candidate (min is kept).
    pub reps: usize,
    /// Thread counts to sweep (clamped to the calibration batch).
    pub thread_candidates: Vec<usize>,
    /// Host the table's decisions were calibrated on. Fresh tuners carry
    /// the current host; loaded tables carry whatever was persisted
    /// (`None` for pre-fingerprint tables).
    pub tuned_host: Option<HostFingerprint>,
}

impl Default for Autotuner {
    fn default() -> Self {
        let pool = ThreadPool::global().size();
        let mut threads = vec![1];
        if pool > 1 {
            threads.push(pool.min(8));
        }
        Autotuner {
            table: BTreeMap::new(),
            budget_secs: 1.5,
            reps: 2,
            thread_candidates: threads,
            tuned_host: Some(HostFingerprint::current()),
        }
    }
}

impl Autotuner {
    /// The cached decision for `key`, if one exists.
    pub fn cached(&self, key: &TuneKey) -> Option<&TunedConfig> {
        self.table.get(key)
    }

    /// Number of cached decisions.
    pub fn len(&self) -> usize {
        self.table.len()
    }

    pub fn is_empty(&self) -> bool {
        self.table.is_empty()
    }

    /// Seed or override a decision (used to preload persisted tables).
    pub fn insert(&mut self, key: TuneKey, cfg: TunedConfig) {
        self.table.insert(key, cfg);
    }

    /// The decision for `key`: cached if present, else calibrated now.
    pub fn tune(&mut self, key: TuneKey) -> Result<TunedConfig> {
        if let Some(c) = self.table.get(&key) {
            return Ok(*c);
        }
        let choice = self.calibrate(&key)?;
        self.table.insert(key, choice);
        Ok(choice)
    }

    /// Measure every candidate on a synthetic layer of the key's shape
    /// and return the fastest configuration.
    fn calibrate(&self, key: &TuneKey) -> Result<TunedConfig> {
        let n = key.neurons;
        let k = key.k;
        // Representative single layer + feature panel; RadixNet::new
        // validates the shape (k <= n, n within u16 indices).
        let net = RadixNet::new(n, 1, k, Topology::Random, 0xA11)?;
        let ell = net.layer_ell(0);
        let csr = ell_to_csr(&ell)?;
        let bias = vec![RuntimeConfig::challenge_bias(n); n];
        let batch = (1usize << 17).div_ceil(n.max(1)).clamp(16, 64);
        let mut rng = Xoshiro256::new(0xFEED);
        let y: Vec<f32> =
            (0..batch * n).map(|_| if rng.next_f32() < 0.3 { 1.0 } else { 0.0 }).collect();
        let edges = (batch * n * k) as f64;

        // Candidate grid. Sorted + deduped so thread clamping cannot
        // produce duplicate measurements; EngineKind order makes the
        // sweep deterministic.
        let mut cands: Vec<(EngineKind, usize, usize, usize)> = vec![(EngineKind::Csr, 1, 0, 1)];
        for &t in &self.thread_candidates {
            let t = t.clamp(1, batch);
            for &mb in &[4usize, 12, 24] {
                cands.push((EngineKind::Ell, mb, 0, t));
                for &slice in &[16usize, 32] {
                    cands.push((EngineKind::Sliced, mb, slice.min(n).max(1), t));
                }
            }
        }
        cands.sort_unstable();
        cands.dedup();

        let mut out = vec![0f32; y.len()];
        let reps = self.reps.max(1);
        let mut time = |run: &mut dyn FnMut(&mut [f32])| -> f64 {
            run(&mut out); // warmup
            let mut best = f64::INFINITY;
            for _ in 0..reps {
                let t = Instant::now();
                run(&mut out);
                best = best.min(t.elapsed().as_secs_f64());
            }
            best.max(1e-9)
        };

        let started = Instant::now();
        let mut best: Option<TunedConfig> = None;
        for (engine, mb, slice, threads) in cands {
            if best.is_some() && started.elapsed().as_secs_f64() > self.budget_secs {
                break; // budget exhausted; keep the best so far
            }
            let secs = match engine {
                EngineKind::Csr => time(&mut |out| CsrEngine.layer(&csr, &bias, &y, out)),
                EngineKind::Ell => {
                    let e = EllEngine::with_mb(threads, mb)?;
                    time(&mut |out| e.layer(&ell, &bias, &y, out))
                }
                EngineKind::Sliced => {
                    let s = SlicedEll::from_ell(&ell, slice)?;
                    let e = SlicedEllEngine::with_mb(threads, mb)?;
                    time(&mut |out| e.layer(&s, &bias, &y, out))
                }
            };
            let eps = edges / secs;
            let better = match &best {
                None => true,
                Some(b) => eps > b.edges_per_sec,
            };
            if better {
                best =
                    Some(TunedConfig { engine, minibatch: mb, slice, threads, edges_per_sec: eps });
            }
        }
        best.ok_or_else(|| anyhow!("no calibration candidate completed"))
    }

    // ------------------------------------------------------- persistence

    /// Why this table should not be trusted on the current host, if any.
    /// `None` means the fingerprint matches and the decisions apply.
    pub fn staleness(&self) -> Option<String> {
        let now = HostFingerprint::current();
        match &self.tuned_host {
            None => Some("table carries no host fingerprint (tuned before spdnn-tune-v1 \
                          grew one)"
                .to_string()),
            Some(h) if *h != now => Some(format!(
                "tuned on {} ({} cpus, pool {}), running on {} ({} cpus, pool {})",
                h.hostname, h.cpus, h.pool, now.hostname, now.cpus, now.pool
            )),
            Some(_) => None,
        }
    }

    pub fn to_json(&self) -> Json {
        let entries: Vec<Json> = self
            .table
            .iter()
            .map(|(key, cfg)| {
                Json::obj(vec![
                    ("neurons", Json::Int(key.neurons as i64)),
                    ("k", Json::Int(key.k as i64)),
                    ("layers", Json::Int(key.layers as i64)),
                    ("engine", Json::Str(cfg.engine.as_str().to_string())),
                    ("minibatch", Json::Int(cfg.minibatch as i64)),
                    ("slice", Json::Int(cfg.slice as i64)),
                    ("threads", Json::Int(cfg.threads as i64)),
                    ("edges_per_sec", Json::Num(cfg.edges_per_sec)),
                ])
            })
            .collect();
        let mut fields = vec![
            ("schema", Json::Str(TUNE_SCHEMA.to_string())),
            ("entries", Json::Arr(entries)),
        ];
        if let Some(host) = &self.tuned_host {
            fields.push(("host", host.to_json()));
        }
        Json::obj(fields)
    }

    /// Merge a serialized table into this tuner. The file's host
    /// fingerprint (or its absence) replaces this tuner's, so staleness
    /// reflects where the *table* came from.
    pub fn load_table(&mut self, doc: &Json) -> Result<()> {
        let schema = doc.req_str("schema")?;
        if schema != TUNE_SCHEMA {
            bail!("tuning table schema {schema:?} is not {TUNE_SCHEMA:?}");
        }
        self.tuned_host = match doc.get("host") {
            Some(h) => Some(HostFingerprint::from_json(h).context("\"host\"")?),
            None => None,
        };
        for e in doc.req_arr("entries")? {
            let key = TuneKey {
                neurons: e.req_usize("neurons")?,
                k: e.req_usize("k")?,
                layers: e.req_usize("layers")?,
            };
            let cfg = TunedConfig {
                engine: EngineKind::parse(e.req_str("engine")?)?,
                minibatch: e.req_usize("minibatch")?,
                slice: e.req_usize("slice")?,
                threads: e.req_usize("threads")?,
                edges_per_sec: e.req_f64("edges_per_sec")?,
            };
            self.table.insert(key, cfg);
        }
        Ok(())
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, format!("{}\n", self.to_json()))
            .with_context(|| format!("writing tuning table {}", path.display()))
    }

    pub fn load(path: &Path) -> Result<Autotuner> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading tuning table {}", path.display()))?;
        let doc = Json::parse(&text).context("parsing tuning table")?;
        let mut tuner = Autotuner::default();
        tuner.load_table(&doc)?;
        Ok(tuner)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_tuner() -> Autotuner {
        Autotuner {
            budget_secs: 0.25,
            reps: 1,
            thread_candidates: vec![1],
            ..Autotuner::default()
        }
    }

    #[test]
    fn tune_returns_and_caches_a_decision() {
        let mut tuner = quick_tuner();
        let key = TuneKey { neurons: 64, k: 4, layers: 3 };
        let first = tuner.tune(key).unwrap();
        assert!(first.edges_per_sec > 0.0);
        assert!(first.minibatch >= 1);
        assert_eq!(tuner.len(), 1);
        // Second call must come from the table (identical, no re-measure).
        let second = tuner.tune(key).unwrap();
        assert_eq!(second, first);
        assert_eq!(tuner.len(), 1);
        assert_eq!(tuner.cached(&key), Some(&first));
    }

    #[test]
    fn invalid_shapes_fail_to_tune() {
        let mut tuner = quick_tuner();
        assert!(tuner.tune(TuneKey { neurons: 16, k: 32, layers: 1 }).is_err());
        assert!(tuner.tune(TuneKey { neurons: 1 << 17, k: 4, layers: 1 }).is_err());
        assert!(tuner.is_empty());
    }

    #[test]
    fn table_json_round_trips() {
        let mut tuner = quick_tuner();
        let key = TuneKey { neurons: 128, k: 8, layers: 7 };
        tuner.insert(
            key,
            TunedConfig {
                engine: EngineKind::Sliced,
                minibatch: 12,
                slice: 32,
                threads: 4,
                edges_per_sec: 1.5e9,
            },
        );
        let doc = tuner.to_json();
        let mut other = quick_tuner();
        other.load_table(&doc).unwrap();
        assert_eq!(other.cached(&key), tuner.cached(&key));
    }

    #[test]
    fn save_and_load_file() {
        let mut tuner = quick_tuner();
        let key = TuneKey { neurons: 64, k: 4, layers: 2 };
        tuner.insert(
            key,
            TunedConfig {
                engine: EngineKind::Ell,
                minibatch: 24,
                slice: 0,
                threads: 2,
                edges_per_sec: 9.0e8,
            },
        );
        let path = std::env::temp_dir().join(format!("spdnn_tune_{}.json", std::process::id()));
        tuner.save(&path).unwrap();
        let loaded = Autotuner::load(&path).unwrap();
        assert_eq!(loaded.cached(&key), tuner.cached(&key));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn bad_schema_rejected() {
        let doc = Json::parse(r#"{"schema":"other","entries":[]}"#).unwrap();
        let mut tuner = quick_tuner();
        assert!(tuner.load_table(&doc).is_err());
    }

    #[test]
    fn fresh_tables_carry_the_current_host_and_are_not_stale() {
        let tuner = quick_tuner();
        assert_eq!(tuner.tuned_host, Some(HostFingerprint::current()));
        assert_eq!(tuner.staleness(), None);
        // The fingerprint survives a serialize/load round trip.
        let mut other = quick_tuner();
        other.load_table(&tuner.to_json()).unwrap();
        assert_eq!(other.tuned_host, tuner.tuned_host);
        assert_eq!(other.staleness(), None);
    }

    #[test]
    fn foreign_host_tables_are_stale() {
        let mut tuner = quick_tuner();
        tuner.tuned_host = Some(HostFingerprint {
            hostname: "some-other-box".into(),
            cpus: 1234,
            pool: 1234,
        });
        let why = tuner.staleness().expect("foreign table must be stale");
        assert!(why.contains("some-other-box"), "staleness should name the host: {why}");
        // And the foreign fingerprint survives persistence.
        let mut loaded = quick_tuner();
        loaded.load_table(&tuner.to_json()).unwrap();
        assert!(loaded.staleness().is_some());
    }

    #[test]
    fn fingerprintless_tables_are_stale() {
        // Pre-fingerprint spdnn-tune-v1 files have no "host" key.
        let doc = Json::parse(
            r#"{"schema":"spdnn-tune-v1","entries":[{"neurons":64,"k":4,"layers":2,
                "engine":"ell","minibatch":12,"slice":0,"threads":1,"edges_per_sec":1.0}]}"#,
        )
        .unwrap();
        let mut tuner = quick_tuner();
        tuner.load_table(&doc).unwrap();
        assert_eq!(tuner.len(), 1, "entries still load");
        assert!(tuner.staleness().is_some(), "but the table is flagged stale");
    }

    #[test]
    fn malformed_host_rejected() {
        let doc =
            Json::parse(r#"{"schema":"spdnn-tune-v1","entries":[],"host":{"hostname":"x"}}"#)
                .unwrap();
        let mut tuner = quick_tuner();
        assert!(tuner.load_table(&doc).is_err());
    }
}
