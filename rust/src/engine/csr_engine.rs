//! Native baseline engine — the Rust analog of the paper's Listing 1.
//!
//! One feature at a time, CSR weight traversal, no minibatch weight reuse:
//! every feature walks the full `displ/index/value` arrays again, exactly
//! the M-fold weight re-read the paper identifies as the baseline
//! bottleneck. Used as the oracle for the optimized engines and as the
//! baseline series in the comparison benches.

use crate::formats::CsrMatrix;

/// Challenge activation: ReLU(x) = max(0, min(x, 32)).
#[inline]
pub fn relu_clip(x: f32) -> f32 {
    x.clamp(0.0, 32.0)
}

/// Baseline CSR engine.
pub struct CsrEngine;

impl CsrEngine {
    /// One layer over a dense row-major feature panel: `[batch, ncols]`
    /// in, `[batch, nrows]` out. Square matrices are the whole-network
    /// case; rectangular ones are row slices of a layer (weight-sharded
    /// cluster ranks compute `[batch, shard_rows]` partial panels).
    pub fn layer(&self, w: &CsrMatrix, bias: &[f32], y_in: &[f32], y_out: &mut [f32]) {
        let (nout, nin) = (w.nrows, w.ncols);
        assert_eq!(bias.len(), nout);
        assert_eq!(y_in.len() % nin.max(1), 0);
        let batch = y_in.len() / nin.max(1);
        assert_eq!(y_out.len(), batch * nout);
        for b in 0..batch {
            let row_in = &y_in[b * nin..(b + 1) * nin];
            let row_out = &mut y_out[b * nout..(b + 1) * nout];
            // Per-feature pass: weights re-read for every feature.
            for i in 0..nout {
                let mut acc = 0.0f32;
                for (c, v) in w.row(i) {
                    acc += row_in[c as usize] * v;
                }
                row_out[i] = relu_clip(acc + bias[i]);
            }
        }
    }

    /// Per-feature activity flags after a layer (the `active[]` counters).
    pub fn active_flags(y: &[f32], neurons: usize) -> Vec<bool> {
        y.chunks_exact(neurons).map(|row| row.iter().any(|&v| v > 0.0)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn relu_clip_profile() {
        assert_eq!(relu_clip(-1.0), 0.0);
        assert_eq!(relu_clip(0.0), 0.0);
        assert_eq!(relu_clip(5.5), 5.5);
        assert_eq!(relu_clip(32.0), 32.0);
        assert_eq!(relu_clip(99.0), 32.0);
    }

    #[test]
    fn layer_known_values() {
        // 2 neurons: w = [[0.5 at col1], [2.0 at col0]] ; bias = [-0.25, 0]
        let w = CsrMatrix::from_rows(2, 2, &[vec![(1, 0.5)], vec![(0, 2.0)]]).unwrap();
        let bias = [-0.25, 0.0];
        let y_in = [1.0, 2.0, /* second feature */ 0.0, 30.0];
        let mut y_out = [0.0; 4];
        CsrEngine.layer(&w, &bias, &y_in, &mut y_out);
        // feature 0: [0.5*2-0.25, 2*1] = [0.75, 2]
        // feature 1: [0.5*30-0.25, 0] = [14.75, 0]
        assert_eq!(y_out, [0.75, 2.0, 14.75, 0.0]);
    }

    #[test]
    fn clipping_applies() {
        let w = CsrMatrix::from_rows(1, 1, &[vec![(0, 100.0)]]).unwrap();
        let mut y_out = [0.0];
        CsrEngine.layer(&w, &[0.0], &[1.0], &mut y_out);
        assert_eq!(y_out, [32.0]);
    }

    #[test]
    fn active_flags() {
        let flags = CsrEngine::active_flags(&[0.0, 0.0, 1.0, 0.0, 0.0, 0.0], 2);
        assert_eq!(flags, vec![false, true, false]);
    }
}
