//! Native Rust inference engines: the Listing-1 baseline (CSR), the
//! row-major panel engine (ELL, minibatch reuse) and the engine-v2
//! sliced-ELL engine (transposed within-slice traversal — the paper's
//! Listing-2 layout), plus the per-network autotuner that picks between
//! them. They serve as oracles for the PJRT path, as the no-PJRT
//! fallback backend, and as comparator series in the benches.

use std::fmt;

use anyhow::{bail, Result};

pub mod autotune;
pub mod csr_engine;
pub mod ell_engine;
pub mod sliced_engine;

pub use autotune::{Autotuner, HostFingerprint, TuneKey, TunedConfig};
pub use csr_engine::{relu_clip, CsrEngine};
pub use ell_engine::{EllEngine, MAX_MB};
pub use sliced_engine::SlicedEllEngine;

/// Which native engine executes layers.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum EngineKind {
    /// Listing-1 baseline: per-feature CSR traversal, no weight reuse.
    Csr,
    /// Row-major ELL panels with minibatch register tiling.
    Ell,
    /// Engine v2: transposed sliced-ELL traversal (Listing 2).
    Sliced,
}

impl EngineKind {
    pub fn parse(s: &str) -> Result<EngineKind> {
        match s {
            "csr" => Ok(EngineKind::Csr),
            "ell" => Ok(EngineKind::Ell),
            "sliced" => Ok(EngineKind::Sliced),
            other => bail!("unknown engine {other:?} (csr|ell|sliced)"),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            EngineKind::Csr => "csr",
            EngineKind::Ell => "ell",
            EngineKind::Sliced => "sliced",
        }
    }
}

impl fmt::Display for EngineKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_kind_round_trips() {
        for kind in [EngineKind::Csr, EngineKind::Ell, EngineKind::Sliced] {
            assert_eq!(EngineKind::parse(kind.as_str()).unwrap(), kind);
            assert_eq!(format!("{kind}"), kind.as_str());
        }
        assert!(EngineKind::parse("warp").is_err());
    }
}
