//! Native Rust inference engines: the Listing-1 baseline (CSR) and the
//! Listing-2 optimized engine (ELL panels, minibatch reuse, threads).
//! They serve as oracles for the PJRT path, as the no-PJRT fallback
//! backend, and as comparator series in the benches.

pub mod csr_engine;
pub mod ell_engine;

pub use csr_engine::{relu_clip, CsrEngine};
pub use ell_engine::EllEngine;
