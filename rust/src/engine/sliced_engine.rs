//! Engine v2 — native execution directly over the paper's transposed
//! sliced-ELL layout (Listing 2, §III.A.3).
//!
//! What the CUDA kernel gets from this layout, and the CPU analog here:
//!
//! * **coalesced weight reads** — within a slice the storage is
//!   transposed (`index[displ + m * slice + lane]`), so the inner lane
//!   loop walks *contiguous* memory the way consecutive CUDA lanes touch
//!   consecutive addresses. The per-row `EllMatrix` walk reads one row's
//!   panel at a time instead;
//! * **register tiling** — each `(idx, val)` element is read once and
//!   reused across a minibatch of `mb` features; accumulators live in a
//!   fixed per-slice panel (`lanes × mb`);
//! * **low padding** — slices pad to the *local* max row length
//!   (`width[s]`), not the global max, so irregular rows cost little
//!   (paper Figure 2);
//! * **fused epilogue** — bias add + `relu_clip` happen on the
//!   accumulator write-out, no second pass over the panel;
//! * **persistent threads** — the feature dimension is split across the
//!   process-wide `util::threadpool` pool, replacing per-layer thread
//!   spawns.
//!
//! Accumulation order per output equals the CSR/ELL order (slices store
//! row entries position-major), so outputs are bit-identical to
//! `CsrEngine` and `EllEngine` — enforced by `tests/engine_equivalence`.

use anyhow::{bail, Result};

use crate::formats::SlicedEll;
use crate::util::threadpool::{pool_chunks_mut, ThreadPool};

use super::csr_engine::relu_clip;
use super::ell_engine::MAX_MB;

/// Native engine over the transposed sliced-ELL layout.
#[derive(Debug)]
pub struct SlicedEllEngine {
    /// Feature-minibatch width (paper MINIBATCH, default 12).
    pub mb: usize,
    /// Worker threads for the feature dimension (jobs run on the
    /// persistent `util::threadpool` global pool).
    pub threads: usize,
}

impl SlicedEllEngine {
    pub fn new(threads: usize) -> SlicedEllEngine {
        SlicedEllEngine { mb: 12, threads: threads.max(1) }
    }

    /// Build with an explicit minibatch width; `mb` must lie in
    /// `1..=MAX_MB` (same contract as `EllEngine::with_mb`).
    pub fn with_mb(threads: usize, mb: usize) -> Result<SlicedEllEngine> {
        if mb == 0 || mb > MAX_MB {
            bail!("minibatch {mb} out of range 1..={MAX_MB}");
        }
        Ok(SlicedEllEngine { mb, threads: threads.max(1) })
    }

    /// One layer over a dense row-major feature panel: `[batch, ncols]`
    /// in, `[batch, nrows]` out (square for whole-network layers,
    /// rectangular for weight-sharded row slices).
    pub fn layer(&self, w: &SlicedEll, bias: &[f32], y_in: &[f32], y_out: &mut [f32]) {
        let (nout, nin) = (w.nrows, w.ncols);
        assert_eq!(bias.len(), nout);
        assert_eq!(y_in.len() % nin.max(1), 0);
        let batch = y_in.len() / nin.max(1);
        assert_eq!(y_out.len(), batch * nout);
        let threads = self.threads.min(batch.max(1));
        if threads <= 1 || nout == 0 {
            self.layer_serial(w, bias, y_in, y_out);
            return;
        }
        let rows = batch.div_ceil(threads);
        pool_chunks_mut(ThreadPool::global(), y_out, rows * nout, |t, out_chunk| {
            let fstart = t * rows;
            let count = out_chunk.len() / nout;
            let in_chunk = &y_in[fstart * nin..(fstart + count) * nin];
            self.layer_serial(w, bias, in_chunk, out_chunk);
        });
    }

    /// Serial sliced kernel (one worker's feature share).
    fn layer_serial(&self, w: &SlicedEll, bias: &[f32], y_in: &[f32], y_out: &mut [f32]) {
        let (nout, nin) = (w.nrows, w.ncols);
        let slice = w.slice;
        let stride = self.mb; // accumulator lane stride (fixed across tails)
        let batch = y_in.len() / nin.max(1);
        // One accumulator panel reused for every slice and minibatch.
        let mut acc = vec![0.0f32; slice * stride];
        let mut bstart = 0;
        while bstart < batch {
            let mb = self.mb.min(batch - bstart);
            let yin = &y_in[bstart * nin..(bstart + mb) * nin];
            let yout = &mut y_out[bstart * nout..(bstart + mb) * nout];
            for s in 0..w.nslices() {
                let (lanes, width, base) = w.slice_parts(s);
                let lo = s * slice;
                acc[..lanes * stride].fill(0.0);
                for m in 0..width {
                    let off = base + m * slice;
                    // Contiguous lane run — the coalescing analog.
                    let idx = &w.index[off..off + lanes];
                    let val = &w.value[off..off + lanes];
                    for lane in 0..lanes {
                        let v = val[lane];
                        if v == 0.0 {
                            continue; // slice-local padding
                        }
                        let c = idx[lane] as usize;
                        let a = &mut acc[lane * stride..lane * stride + mb];
                        // Register tiling: one (idx, val) element feeds
                        // the whole minibatch.
                        for (f, slot) in a.iter_mut().enumerate() {
                            *slot += yin[f * nin + c] * v;
                        }
                    }
                }
                // Fused bias + clipped-ReLU epilogue.
                for lane in 0..lanes {
                    let i = lo + lane;
                    let b = bias[i];
                    for f in 0..mb {
                        yout[f * nout + i] = relu_clip(acc[lane * stride + f] + b);
                    }
                }
            }
            bstart += mb;
        }
    }

    /// One layer over a *compacted* active-feature panel (the
    /// coordinator's pruning path): only the first `active` features of
    /// `y_in`/`y_out` are touched.
    pub fn layer_active(
        &self,
        w: &SlicedEll,
        bias: &[f32],
        y_in: &[f32],
        y_out: &mut [f32],
        active: usize,
    ) {
        assert!(active * w.ncols <= y_in.len());
        self.layer(w, bias, &y_in[..active * w.ncols], &mut y_out[..active * w.nrows]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::csr_engine::CsrEngine;
    use crate::engine::ell_engine::EllEngine;
    use crate::formats::convert::ell_to_csr;
    use crate::formats::SlicedEll;
    use crate::radixnet::{RadixNet, Topology};
    use crate::util::prng::Xoshiro256;
    use crate::util::proptest::{self, Runner};

    fn random_problem(
        rng: &mut Xoshiro256,
        n: usize,
        k: usize,
        batch: usize,
    ) -> (crate::formats::EllMatrix, Vec<f32>, Vec<f32>) {
        let net = RadixNet::new(n, 1, k, Topology::Random, rng.next_u64()).unwrap();
        let mut w = net.layer_ell(0);
        for v in w.value.iter_mut() {
            *v = rng.next_range_f32(-0.5, 0.5);
        }
        let bias: Vec<f32> = (0..n).map(|_| rng.next_range_f32(-0.3, 0.1)).collect();
        let y = proptest::sparse_binary(rng, batch * n, 0.3);
        (w, bias, y)
    }

    #[test]
    fn matches_csr_engine_bit_exact() {
        Runner::new(24, 0x51E).run("sliced-vs-csr", |rng| {
            let n = *proptest::choose(rng, &[16usize, 32, 64]);
            let k = proptest::usize_in(rng, 1, 8.min(n));
            let batch = proptest::usize_in(rng, 1, 20);
            let slice = *proptest::choose(rng, &[1usize, 2, 7, 16]);
            let (w, bias, y) = random_problem(rng, n, k, batch);
            let csr = ell_to_csr(&w).unwrap();
            let sliced = SlicedEll::from_ell(&w, slice).unwrap();
            let mut a = vec![0.0; y.len()];
            let mut b = vec![0.0; y.len()];
            SlicedEllEngine::new(1).layer(&sliced, &bias, &y, &mut a);
            CsrEngine.layer(&csr, &bias, &y, &mut b);
            if a != b {
                return Err(format!("outputs differ (n={n} k={k} batch={batch} slice={slice})"));
            }
            Ok(())
        });
    }

    #[test]
    fn minibatch_and_slice_do_not_change_results() {
        let mut rng = Xoshiro256::new(0x2B);
        let (w, bias, y) = random_problem(&mut rng, 64, 8, 30);
        let base = SlicedEll::from_ell(&w, 16).unwrap();
        let mut want = vec![0.0; y.len()];
        SlicedEllEngine::with_mb(1, 1).unwrap().layer(&base, &bias, &y, &mut want);
        for mb in [2, 5, 12, 30, 64] {
            for slice in [1usize, 4, 16, 64] {
                let s = SlicedEll::from_ell(&w, slice).unwrap();
                let mut got = vec![0.0; y.len()];
                SlicedEllEngine::with_mb(1, mb).unwrap().layer(&s, &bias, &y, &mut got);
                assert_eq!(got, want, "mb={mb} slice={slice}");
            }
        }
    }

    #[test]
    fn threading_does_not_change_results() {
        let mut rng = Xoshiro256::new(0x2C);
        let (w, bias, y) = random_problem(&mut rng, 32, 4, 48);
        let s = SlicedEll::from_ell(&w, 8).unwrap();
        let mut want = vec![0.0; y.len()];
        SlicedEllEngine::new(1).layer(&s, &bias, &y, &mut want);
        for t in [2, 3, 4, 8] {
            let mut got = vec![0.0; y.len()];
            SlicedEllEngine::new(t).layer(&s, &bias, &y, &mut got);
            assert_eq!(got, want, "threads={t}");
        }
    }

    #[test]
    fn matches_ell_engine_bit_exact_on_fixed_case() {
        let mut rng = Xoshiro256::new(0x2D);
        let (w, bias, y) = random_problem(&mut rng, 64, 4, 17);
        let s = SlicedEll::from_ell(&w, 32).unwrap();
        let mut a = vec![0.0; y.len()];
        let mut b = vec![0.0; y.len()];
        SlicedEllEngine::new(1).layer(&s, &bias, &y, &mut a);
        EllEngine::new(1).layer(&w, &bias, &y, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn layer_active_prefix() {
        let mut rng = Xoshiro256::new(0x2E);
        let (w, bias, y) = random_problem(&mut rng, 32, 4, 10);
        let s = SlicedEll::from_ell(&w, 8).unwrap();
        let mut full = vec![0.0; y.len()];
        SlicedEllEngine::new(1).layer(&s, &bias, &y, &mut full);
        let mut partial = vec![0.0; y.len()];
        SlicedEllEngine::new(1).layer_active(&s, &bias, &y, &mut partial, 4);
        assert_eq!(&partial[..4 * 32], &full[..4 * 32]);
        assert!(partial[4 * 32..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn with_mb_rejects_out_of_range() {
        assert!(SlicedEllEngine::with_mb(1, 0).is_err());
        assert!(SlicedEllEngine::with_mb(1, MAX_MB + 1).is_err());
        assert_eq!(SlicedEllEngine::with_mb(2, MAX_MB).unwrap().mb, MAX_MB);
    }

    /// Rectangular (weight-sharded) layers: running each row slice of a
    /// layer and stitching the partial panels back together must be
    /// bit-identical to the full square layer — on all three engines.
    #[test]
    fn rectangular_row_slices_match_full_layer_bit_exact() {
        use crate::coordinator::partition::partition_even;
        Runner::new(16, 0x5A4D).run("row-slices-vs-full", |rng| {
            let n = *proptest::choose(rng, &[32usize, 64]);
            let batch = proptest::usize_in(rng, 1, 12);
            let ranks = proptest::usize_in(rng, 1, 5); // often ranks ∤ n
            let (w, bias, y) = random_problem(rng, n, 8.min(n), batch);
            let full_sliced = SlicedEll::from_ell(&w, 8).unwrap();
            let mut want = vec![0.0; y.len()];
            SlicedEllEngine::new(1).layer(&full_sliced, &bias, &y, &mut want);

            let mut got = vec![0.0; y.len()];
            for part in partition_even(n, ranks) {
                let sub = w.row_slice(part.start, part.count);
                let sub_bias = &bias[part.start..part.start + part.count];
                let mut partial = vec![0.0; batch * part.count];
                match part.worker % 3 {
                    0 => SlicedEllEngine::new(2).layer(
                        &SlicedEll::from_ell(&sub, 8).unwrap(),
                        sub_bias,
                        &y,
                        &mut partial,
                    ),
                    1 => EllEngine::new(2).layer(&sub, sub_bias, &y, &mut partial),
                    _ => CsrEngine.layer(&ell_to_csr(&sub).unwrap(), sub_bias, &y, &mut partial),
                }
                for f in 0..batch {
                    got[f * n + part.start..f * n + part.start + part.count]
                        .copy_from_slice(&partial[f * part.count..(f + 1) * part.count]);
                }
            }
            if got != want {
                return Err(format!("stitched output differs (n={n} ranks={ranks})"));
            }
            Ok(())
        });
    }
}
