//! Native optimized engine — the Rust analog of the paper's Listing 2,
//! used as (a) the oracle for the PJRT path, (b) the no-PJRT fallback
//! backend, and (c) the optimized series in native comparison benches.
//!
//! Optimizations mirrored from the CUDA kernel:
//! * **register tiling** — features are processed in minibatches of `mb`;
//!   each weight panel row `(idx, val)` is read once and reused across the
//!   whole minibatch (the accumulator panel lives in L1/registers);
//! * **ELL panels** — contiguous `[n, k]` index/value storage with u16
//!   indices (coalescing/compactness analog);
//! * **thread parallelism** — the feature dimension is split across OS
//!   threads (the multi-SM analog).

use anyhow::{bail, Result};

use crate::formats::EllMatrix;
use crate::util::threadpool::{pool_chunks_mut, ThreadPool};

use super::csr_engine::relu_clip;

/// Upper bound on the minibatch accumulator panel (stack array).
pub const MAX_MB: usize = 64;

/// Optimized native engine.
#[derive(Debug)]
pub struct EllEngine {
    /// Feature-minibatch width (paper MINIBATCH, default 12).
    pub mb: usize,
    /// Worker threads for the feature dimension (jobs run on the
    /// persistent `util::threadpool` global pool).
    pub threads: usize,
}

impl EllEngine {
    pub fn new(threads: usize) -> EllEngine {
        EllEngine { mb: 12, threads: threads.max(1) }
    }

    /// Build with an explicit minibatch width.
    ///
    /// `mb` must lie in `1..=MAX_MB` — the accumulator panel is a fixed
    /// stack array, so an out-of-range width is an error rather than the
    /// silent clamp earlier revisions applied.
    pub fn with_mb(threads: usize, mb: usize) -> Result<EllEngine> {
        if mb == 0 || mb > MAX_MB {
            bail!("minibatch {mb} out of range 1..={MAX_MB}");
        }
        Ok(EllEngine { mb, threads: threads.max(1) })
    }

    /// One layer over a dense row-major feature panel: `[batch, ncols]`
    /// in, `[batch, nrows]` out (square for whole-network layers,
    /// rectangular for weight-sharded row slices).
    ///
    /// The batch is split across pool workers at *feature* granularity so
    /// no worker ever sees a partial feature row.
    pub fn layer(&self, w: &EllMatrix, bias: &[f32], y_in: &[f32], y_out: &mut [f32]) {
        let (nout, nin) = (w.nrows, w.ncols);
        assert_eq!(bias.len(), nout);
        assert_eq!(y_in.len() % nin.max(1), 0);
        let batch = y_in.len() / nin.max(1);
        assert_eq!(y_out.len(), batch * nout);
        let threads = self.threads.min(batch.max(1));
        if threads <= 1 || nout == 0 {
            self.layer_serial(w, bias, y_in, y_out);
            return;
        }
        let rows = batch.div_ceil(threads);
        pool_chunks_mut(ThreadPool::global(), y_out, rows * nout, |t, out_chunk| {
            let fstart = t * rows;
            let count = out_chunk.len() / nout;
            let in_chunk = &y_in[fstart * nin..(fstart + count) * nin];
            self.layer_serial(w, bias, in_chunk, out_chunk);
        });
    }

    /// Serial minibatched kernel (one thread's share).
    fn layer_serial(&self, w: &EllMatrix, bias: &[f32], y_in: &[f32], y_out: &mut [f32]) {
        let (nout, nin) = (w.nrows, w.ncols);
        let k = w.k;
        let batch = y_in.len() / nin.max(1);
        let mut bstart = 0;
        while bstart < batch {
            let mb = self.mb.min(batch - bstart);
            let yin = &y_in[bstart * nin..(bstart + mb) * nin];
            let yout = &mut y_out[bstart * nout..(bstart + mb) * nout];
            // Register tiling: one (idx, val) panel row feeds `mb` features.
            for i in 0..nout {
                let idx = &w.index[i * k..(i + 1) * k];
                let val = &w.value[i * k..(i + 1) * k];
                let mut acc = [0.0f32; MAX_MB];
                for (&c, &v) in idx.iter().zip(val) {
                    if v == 0.0 {
                        continue; // skip ELL padding
                    }
                    let c = c as usize;
                    for f in 0..mb {
                        acc[f] += yin[f * nin + c] * v;
                    }
                }
                let b = bias[i];
                for f in 0..mb {
                    yout[f * nout + i] = relu_clip(acc[f] + b);
                }
            }
            bstart += mb;
        }
    }

    /// One layer over a *compacted* active-feature panel: only the listed
    /// features exist in `y_in`/`y_out` (the coordinator's pruning path).
    pub fn layer_active(
        &self,
        w: &EllMatrix,
        bias: &[f32],
        y_in: &[f32],
        y_out: &mut [f32],
        active: usize,
    ) {
        assert!(active * w.ncols <= y_in.len());
        self.layer(w, bias, &y_in[..active * w.ncols], &mut y_out[..active * w.nrows]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::csr_engine::CsrEngine;
    use crate::formats::convert::ell_to_csr;
    use crate::radixnet::{RadixNet, Topology};
    use crate::util::prng::Xoshiro256;
    use crate::util::proptest::{self, Runner};

    fn random_problem(
        rng: &mut Xoshiro256,
        n: usize,
        k: usize,
        batch: usize,
    ) -> (EllMatrix, Vec<f32>, Vec<f32>) {
        let net = RadixNet::new(n, 1, k, Topology::Random, rng.next_u64()).unwrap();
        let mut w = net.layer_ell(0);
        // Randomize values away from the constant 1/16 for a harder test.
        for v in w.value.iter_mut() {
            *v = rng.next_range_f32(-0.5, 0.5);
        }
        let bias: Vec<f32> = (0..n).map(|_| rng.next_range_f32(-0.3, 0.1)).collect();
        let y = proptest::sparse_binary(rng, batch * n, 0.3);
        (w, bias, y)
    }

    #[test]
    fn matches_csr_engine_oracle() {
        Runner::new(24, 0xE11).run("ell-vs-csr", |rng| {
            let n = *proptest::choose(rng, &[16usize, 32, 64]);
            let k = proptest::usize_in(rng, 1, 8.min(n));
            let batch = proptest::usize_in(rng, 1, 20);
            let (w, bias, y) = random_problem(rng, n, k, batch);
            let csr = ell_to_csr(&w).unwrap();
            let mut a = vec![0.0; y.len()];
            let mut b = vec![0.0; y.len()];
            EllEngine::new(1).layer(&w, &bias, &y, &mut a);
            CsrEngine.layer(&csr, &bias, &y, &mut b);
            for (i, (x, y)) in a.iter().zip(&b).enumerate() {
                if (x - y).abs() > 1e-4 {
                    return Err(format!("mismatch at {i}: {x} vs {y}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn minibatch_width_does_not_change_results() {
        let mut rng = Xoshiro256::new(77);
        let (w, bias, y) = random_problem(&mut rng, 64, 8, 30);
        let mut want = vec![0.0; y.len()];
        EllEngine::with_mb(1, 1).unwrap().layer(&w, &bias, &y, &mut want);
        for mb in [2, 4, 12, 30, 64] {
            let mut got = vec![0.0; y.len()];
            EllEngine::with_mb(1, mb).unwrap().layer(&w, &bias, &y, &mut got);
            assert_eq!(got, want, "mb={mb}");
        }
    }

    #[test]
    fn with_mb_rejects_out_of_range() {
        assert!(EllEngine::with_mb(1, 0).is_err());
        assert!(EllEngine::with_mb(1, MAX_MB + 1).is_err());
        assert!(EllEngine::with_mb(1, 1000).is_err());
        assert_eq!(EllEngine::with_mb(1, 1).unwrap().mb, 1);
        assert_eq!(EllEngine::with_mb(1, MAX_MB).unwrap().mb, MAX_MB);
        // The error message names the accepted range.
        let err = EllEngine::with_mb(1, 65).unwrap_err().to_string();
        assert!(err.contains("1..=64"), "unexpected message: {err}");
    }

    #[test]
    fn threading_does_not_change_results() {
        let mut rng = Xoshiro256::new(78);
        let (w, bias, y) = random_problem(&mut rng, 32, 4, 48);
        let mut want = vec![0.0; y.len()];
        EllEngine::new(1).layer(&w, &bias, &y, &mut want);
        for t in [2, 3, 4, 8] {
            let mut got = vec![0.0; y.len()];
            EllEngine::new(t).layer(&w, &bias, &y, &mut got);
            assert_eq!(got, want, "threads={t}");
        }
    }

    #[test]
    fn layer_active_prefix() {
        let mut rng = Xoshiro256::new(79);
        let (w, bias, y) = random_problem(&mut rng, 32, 4, 10);
        let mut full = vec![0.0; y.len()];
        EllEngine::new(1).layer(&w, &bias, &y, &mut full);
        let mut partial = vec![0.0; y.len()];
        EllEngine::new(1).layer_active(&w, &bias, &y, &mut partial, 4);
        assert_eq!(&partial[..4 * 32], &full[..4 * 32]);
        assert!(partial[4 * 32..].iter().all(|&v| v == 0.0));
    }
}
