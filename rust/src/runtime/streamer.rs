//! Out-of-core weight streaming with double buffering (paper §III.B.1).
//!
//! Replicating all layer weights per GPU makes large networks infeasible
//! for 16 GB devices; the paper streams each layer's weights from CPU
//! memory and hides the copy behind the previous layer's kernel with a
//! double buffer. Here the "CPU memory" is the packed weight file and the
//! "GPU" is the PJRT device: a prefetch thread reads + decodes layer l+1
//! while the main thread executes layer l. The `sync_channel(1)` bound
//! gives exactly two buffers in flight (one ready, one being filled).

use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::thread::JoinHandle;

use anyhow::{anyhow, bail, Result};

use crate::data::binio;
use crate::formats::EllMatrix;

/// A source of per-layer weight panels, in layer order.
pub enum WeightStreamer {
    /// All layers resident in memory (weights-fit case).
    Memory { layers: Vec<EllMatrix>, next: usize },
    /// Out-of-core: prefetch thread + double buffer.
    Stream {
        rx: mpsc::Receiver<Result<EllMatrix>>,
        handle: Option<JoinHandle<()>>,
        path: PathBuf,
        remaining: usize,
    },
}

impl WeightStreamer {
    /// In-memory source (no streaming).
    pub fn from_memory(layers: Vec<EllMatrix>) -> WeightStreamer {
        WeightStreamer::Memory { layers, next: 0 }
    }

    /// Out-of-core source over a packed weight file written by
    /// [`binio::write_weights`]. `layers` is the number of layers to
    /// stream (validated against the file on first read).
    pub fn from_file(path: &Path, layers: usize) -> WeightStreamer {
        // Capacity 1 => producer runs at most one layer ahead: the double
        // buffer. A larger bound would only add memory, not overlap.
        let (tx, rx) = mpsc::sync_channel::<Result<EllMatrix>>(1);
        let p = path.to_path_buf();
        let handle = std::thread::spawn(move || {
            for l in 0..layers {
                let res = binio::read_weights_layer(&p, l);
                let failed = res.is_err();
                if tx.send(res).is_err() || failed {
                    return; // consumer dropped, or error delivered
                }
            }
        });
        WeightStreamer::Stream {
            rx,
            handle: Some(handle),
            path: path.to_path_buf(),
            remaining: layers,
        }
    }

    /// Number of layers still to be delivered.
    pub fn remaining(&self) -> usize {
        match self {
            WeightStreamer::Memory { layers, next } => layers.len() - next,
            WeightStreamer::Stream { remaining, .. } => *remaining,
        }
    }

    /// Whether this source streams out-of-core.
    pub fn is_streaming(&self) -> bool {
        matches!(self, WeightStreamer::Stream { .. })
    }

    /// Take the next layer's weights. Errors if exhausted or the prefetch
    /// thread hit an IO/decode failure.
    pub fn next_layer(&mut self) -> Result<EllMatrix> {
        match self {
            WeightStreamer::Memory { layers, next } => {
                if *next >= layers.len() {
                    bail!("weight stream exhausted after {} layers", layers.len());
                }
                *next += 1;
                Ok(layers[*next - 1].clone())
            }
            WeightStreamer::Stream { rx, path, remaining, .. } => {
                if *remaining == 0 {
                    bail!("weight stream exhausted ({})", path.display());
                }
                *remaining -= 1;
                rx.recv()
                    .map_err(|_| anyhow!("prefetch thread died ({})", path.display()))?
            }
        }
    }
}

impl Drop for WeightStreamer {
    fn drop(&mut self) {
        if let WeightStreamer::Stream { rx, handle, .. } = self {
            // Drain so the producer unblocks, then join.
            while rx.try_recv().is_ok() {}
            if let Some(h) = handle.take() {
                // Producer may still be blocked on send; dropping rx first
                // is not possible here, so drain until disconnected.
                loop {
                    match rx.recv_timeout(std::time::Duration::from_millis(50)) {
                        Ok(_) => continue,
                        Err(_) => break,
                    }
                }
                let _ = h.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::radixnet::{RadixNet, Topology};

    fn layers(n: usize, l: usize) -> Vec<EllMatrix> {
        let net = RadixNet::new(n, l, 4, Topology::Random, 3).unwrap();
        (0..l).map(|i| net.layer_ell(i)).collect()
    }

    fn tmp(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("spdnn_stream_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d.join(name)
    }

    #[test]
    fn memory_source_in_order() {
        let ls = layers(32, 5);
        let mut s = WeightStreamer::from_memory(ls.clone());
        assert!(!s.is_streaming());
        for (i, want) in ls.iter().enumerate() {
            assert_eq!(s.remaining(), 5 - i);
            assert_eq!(&s.next_layer().unwrap(), want);
        }
        assert!(s.next_layer().is_err());
    }

    #[test]
    fn file_stream_matches_memory() {
        let ls = layers(64, 6);
        let path = tmp("w.bin");
        binio::write_weights(&path, &ls).unwrap();
        let mut s = WeightStreamer::from_file(&path, 6);
        assert!(s.is_streaming());
        for want in &ls {
            assert_eq!(&s.next_layer().unwrap(), want);
        }
        assert!(s.next_layer().is_err());
    }

    #[test]
    fn missing_file_errors_on_first_next() {
        let mut s = WeightStreamer::from_file(Path::new("/nonexistent/w.bin"), 3);
        assert!(s.next_layer().is_err());
    }

    #[test]
    fn truncated_file_errors_midstream() {
        let ls = layers(64, 4);
        let path = tmp("trunc.bin");
        binio::write_weights(&path, &ls).unwrap();
        // Chop the file after ~2.5 layers.
        let full = std::fs::read(&path).unwrap();
        let keep = 44 + (64 * 4 * 6) * 2 + (64 * 4 * 6) / 2;
        std::fs::write(&path, &full[..keep]).unwrap();
        let mut s = WeightStreamer::from_file(&path, 4);
        assert!(s.next_layer().is_ok());
        assert!(s.next_layer().is_ok());
        let mut hit_error = false;
        for _ in 0..2 {
            if s.next_layer().is_err() {
                hit_error = true;
                break;
            }
        }
        assert!(hit_error, "truncation must surface as an error");
    }

    #[test]
    fn early_drop_joins_producer() {
        let ls = layers(64, 8);
        let path = tmp("drop.bin");
        binio::write_weights(&path, &ls).unwrap();
        let mut s = WeightStreamer::from_file(&path, 8);
        let _ = s.next_layer().unwrap();
        drop(s); // must not hang or leak the prefetch thread
    }
}
