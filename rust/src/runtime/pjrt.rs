//! PJRT execution wrapper around the `xla` crate.
//!
//! Loads the HLO-text artifacts emitted by `python/compile/aot.py`,
//! compiles them on the PJRT CPU client and executes layer steps from the
//! coordinator's hot loop. HLO *text* is the interchange format (the
//! crate's xla_extension 0.5.1 rejects jax>=0.5 serialized protos).
//!
//! Thread model: the `xla` crate's handles are not `Send`, so every
//! coordinator worker ("GPU rank") owns its own [`PjrtBackend`] — which is
//! exactly the paper's MPI model: weights replicated per rank, features
//! partitioned (§IV.C).
//!
//! The `xla` crate is an optional dependency gated behind the
//! `pjrt-xla` + `xla-sys` feature pair (it needs a downloaded
//! xla_extension). Without both features a build-time stub (end of this
//! file) keeps the whole crate compiling; constructing a
//! [`PjrtBackend`] then fails with a clear error and the native engine
//! remains the fallback backend.

use anyhow::{anyhow, bail, Context, Result};

use super::manifest::Artifact;

/// One PJRT client ("device") plus compile services.
pub struct PjrtBackend {
    client: xla::PjRtClient,
}

impl PjrtBackend {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<PjrtBackend> {
        let client = xla::PjRtClient::cpu().map_err(wrap_xla)?;
        Ok(PjrtBackend { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one artifact.
    pub fn compile(&self, artifact: &Artifact) -> Result<CompiledLayer> {
        let path = artifact
            .path
            .to_str()
            .ok_or_else(|| anyhow!("non-utf8 artifact path"))?;
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(wrap_xla)
            .with_context(|| format!("loading HLO text {path}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(wrap_xla)
            .with_context(|| format!("compiling {}", artifact.name))?;
        Ok(CompiledLayer { artifact: artifact.clone(), exe })
    }
}

/// Weight tensors of one layer, staged as XLA literals once and reused for
/// every dispatch that layer serves (all minibatches, all epochs).
pub struct LayerLiterals {
    pub idx: xla::Literal,
    pub val: xla::Literal,
    pub bias: xla::Literal,
    pub neurons: usize,
    pub k: usize,
}

impl LayerLiterals {
    /// Build from host panels (`[n, k]` u16 idx / f32 val, `[n]` f32 bias).
    pub fn new(
        idx: &[u16],
        val: &[f32],
        bias: &[f32],
        neurons: usize,
        k: usize,
    ) -> Result<LayerLiterals> {
        if idx.len() != neurons * k || val.len() != neurons * k || bias.len() != neurons {
            bail!("weight panel shape mismatch");
        }
        let idx_bytes: Vec<u8> = idx.iter().flat_map(|x| x.to_le_bytes()).collect();
        let idx = xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::U16,
            &[neurons, k],
            &idx_bytes,
        )
        .map_err(wrap_xla)?;
        let val = xla::Literal::vec1(val).reshape(&[neurons as i64, k as i64]).map_err(wrap_xla)?;
        let bias = xla::Literal::vec1(bias);
        Ok(LayerLiterals { idx, val, bias, neurons, k })
    }
}

/// Output of one layer dispatch.
#[derive(Clone, Debug, PartialEq)]
pub struct LayerOut {
    /// Activated features, `[capacity, neurons]` row-major.
    pub y_next: Vec<f32>,
    /// Per-feature activity flags, `[capacity]`.
    pub active: Vec<i32>,
}

/// A compiled layer-step executable.
pub struct CompiledLayer {
    pub artifact: Artifact,
    exe: xla::PjRtLoadedExecutable,
}

impl CompiledLayer {
    pub fn capacity(&self) -> usize {
        self.artifact.capacity
    }

    pub fn neurons(&self) -> usize {
        self.artifact.neurons
    }

    /// Execute one layer step over a [capacity, neurons] feature panel.
    ///
    /// `y` shorter than the full panel is zero-padded to capacity (the
    /// static-shape stand-in for the CUDA grid sized by the live feature
    /// count); flags for padded rows come back 0 and are ignored upstream.
    pub fn run(&self, y: &[f32], w: &LayerLiterals) -> Result<LayerOut> {
        let cap = self.artifact.capacity;
        let n = self.artifact.neurons;
        if w.neurons != n || w.k != self.artifact.k {
            bail!(
                "weights do not match executable ({}x{} vs {}x{})",
                w.neurons,
                w.k,
                n,
                self.artifact.k
            );
        }
        if y.len() > cap * n || y.len() % n != 0 {
            bail!("feature panel of {} values does not fit capacity {cap}x{n}", y.len());
        }
        let y_lit = if y.len() == cap * n {
            xla::Literal::vec1(y).reshape(&[cap as i64, n as i64]).map_err(wrap_xla)?
        } else {
            let mut padded = vec![0f32; cap * n];
            padded[..y.len()].copy_from_slice(y);
            xla::Literal::vec1(&padded).reshape(&[cap as i64, n as i64]).map_err(wrap_xla)?
        };
        // `execute` borrows its arguments, so the staged weight literals
        // are reused without copying (the paper's "constructed once prior
        // to inference, reused for all features").
        let args: [&xla::Literal; 4] = [&y_lit, &w.idx, &w.val, &w.bias];
        let result = self.exe.execute::<&xla::Literal>(&args).map_err(wrap_xla)?;
        let tuple = result[0][0].to_literal_sync().map_err(wrap_xla)?;
        let (y_next_lit, active_lit) = tuple.to_tuple2().map_err(wrap_xla)?;
        Ok(LayerOut {
            y_next: y_next_lit.to_vec::<f32>().map_err(wrap_xla)?,
            active: active_lit.to_vec::<i32>().map_err(wrap_xla)?,
        })
    }
}

/// Stacked weights of a fused multi-layer (scan) artifact, staged once.
pub struct ScanLiterals {
    pub idx: xla::Literal,
    pub val: xla::Literal,
    pub bias: xla::Literal,
    pub layers: usize,
    pub neurons: usize,
    pub k: usize,
}

impl ScanLiterals {
    /// Build from per-layer panels (all layers resident — the scan
    /// executable cannot stream out-of-core; that is its tradeoff).
    pub fn new(layers: &[crate::formats::EllMatrix], bias: &[f32]) -> Result<ScanLiterals> {
        if layers.is_empty() {
            bail!("scan needs at least one layer");
        }
        let n = layers[0].nrows;
        let k = layers[0].k;
        if layers.iter().any(|l| l.nrows != n || l.k != k) {
            bail!("scan layers must share [neurons, k]");
        }
        let mut idx_bytes = Vec::with_capacity(layers.len() * n * k * 2);
        let mut val_flat = Vec::with_capacity(layers.len() * n * k);
        for l in layers {
            idx_bytes.extend(l.index.iter().flat_map(|x| x.to_le_bytes()));
            val_flat.extend_from_slice(&l.value);
        }
        let idx = xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::U16,
            &[layers.len(), n, k],
            &idx_bytes,
        )
        .map_err(wrap_xla)?;
        let val = xla::Literal::vec1(&val_flat)
            .reshape(&[layers.len() as i64, n as i64, k as i64])
            .map_err(wrap_xla)?;
        let bias = xla::Literal::vec1(bias);
        Ok(ScanLiterals { idx, val, bias, layers: layers.len(), neurons: n, k })
    }
}

impl CompiledLayer {
    /// Execute a fused multi-layer (scan_opt) artifact: the whole network
    /// in ONE dispatch. Used by the dispatch-amortization ablation.
    pub fn run_scan(&self, y: &[f32], w: &ScanLiterals) -> Result<LayerOut> {
        let cap = self.artifact.capacity;
        let n = self.artifact.neurons;
        if self.artifact.layers != Some(w.layers) {
            bail!(
                "scan executable fuses {:?} layers, weights carry {}",
                self.artifact.layers,
                w.layers
            );
        }
        if w.neurons != n || w.k != self.artifact.k {
            bail!("scan weights do not match executable");
        }
        if y.len() > cap * n || y.len() % n != 0 {
            bail!("feature panel of {} values does not fit capacity {cap}x{n}", y.len());
        }
        let y_lit = if y.len() == cap * n {
            xla::Literal::vec1(y).reshape(&[cap as i64, n as i64]).map_err(wrap_xla)?
        } else {
            let mut padded = vec![0f32; cap * n];
            padded[..y.len()].copy_from_slice(y);
            xla::Literal::vec1(&padded).reshape(&[cap as i64, n as i64]).map_err(wrap_xla)?
        };
        let args: [&xla::Literal; 4] = [&y_lit, &w.idx, &w.val, &w.bias];
        let result = self.exe.execute::<&xla::Literal>(&args).map_err(wrap_xla)?;
        let tuple = result[0][0].to_literal_sync().map_err(wrap_xla)?;
        let (y_next_lit, active_lit) = tuple.to_tuple2().map_err(wrap_xla)?;
        Ok(LayerOut {
            y_next: y_next_lit.to_vec::<f32>().map_err(wrap_xla)?,
            active: active_lit.to_vec::<i32>().map_err(wrap_xla)?,
        })
    }
}

/// The xla crate error type does not implement std::error::Error + Send +
/// Sync uniformly; normalise through strings.
fn wrap_xla<E: std::fmt::Debug>(e: E) -> anyhow::Error {
    anyhow!("xla error: {e:?}")
}

// ---------------------------------------------------------------------------
// Build-time stub for the optional `xla` crate.
//
// The stub mirrors exactly the API surface this module touches; every
// entry point that would reach XLA returns the same "built without the
// real bindings" error, so `PjrtBackend::cpu()` fails fast and the
// coordinator falls back to (or the caller selects) the native engine.
//
// It compiles in unless BOTH `pjrt-xla` and `xla-sys` are enabled:
// `pjrt-xla` alone exercises the feature surface against the stub (the
// CI feature-matrix leg), `xla-sys` additionally links the real crate
// (requires the xla dependency uncommented in Cargo.toml). This keeps
// `cargo build`/`cargo test` working in environments where the xla
// dependency cannot be fetched.
// ---------------------------------------------------------------------------

#[cfg(not(all(feature = "pjrt-xla", feature = "xla-sys")))]
#[doc(hidden)]
pub mod xla {
    // Public (not private) because LayerLiterals/ScanLiterals expose
    // these types through pub fields; a private module would trip the
    // `private_interfaces` lint on every default build.
    #![allow(dead_code)]

    pub type Error = String;

    fn unavailable() -> Error {
        "spdnn was built without the real XLA bindings; the PJRT backend is \
         unavailable (uncomment the xla dependency in Cargo.toml and rebuild \
         with --features pjrt-xla,xla-sys, or use --backend native)"
            .to_string()
    }

    #[derive(Clone, Copy, Debug)]
    pub enum ElementType {
        U16,
    }

    pub struct PjRtClient;

    impl PjRtClient {
        pub fn cpu() -> Result<PjRtClient, Error> {
            Err(unavailable())
        }

        pub fn platform_name(&self) -> String {
            "stub".to_string()
        }

        pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
            Err(unavailable())
        }
    }

    pub struct HloModuleProto;

    impl HloModuleProto {
        pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
            Err(unavailable())
        }
    }

    pub struct XlaComputation;

    impl XlaComputation {
        pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
            XlaComputation
        }
    }

    pub struct Literal;

    impl Literal {
        pub fn vec1(_values: &[f32]) -> Literal {
            Literal
        }

        pub fn create_from_shape_and_untyped_data(
            _ty: ElementType,
            _shape: &[usize],
            _data: &[u8],
        ) -> Result<Literal, Error> {
            Err(unavailable())
        }

        pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
            Err(unavailable())
        }

        pub fn to_tuple2(self) -> Result<(Literal, Literal), Error> {
            Err(unavailable())
        }

        pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
            Err(unavailable())
        }
    }

    pub struct PjRtBuffer;

    impl PjRtBuffer {
        pub fn to_literal_sync(&self) -> Result<Literal, Error> {
            Err(unavailable())
        }
    }

    pub struct PjRtLoadedExecutable;

    impl PjRtLoadedExecutable {
        pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
            Err(unavailable())
        }
    }
}
