//! Artifact manifest — typed view of `artifacts/manifest.json` written by
//! `python/compile/aot.py`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// Element type of an artifact input/output.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    U16,
    I32,
}

impl Dtype {
    pub fn parse(s: &str) -> Result<Dtype> {
        match s {
            "f32" => Ok(Dtype::F32),
            "u16" => Ok(Dtype::U16),
            "i32" => Ok(Dtype::I32),
            _ => bail!("unknown dtype {s:?}"),
        }
    }

    pub fn size_bytes(self) -> usize {
        match self {
            Dtype::F32 | Dtype::I32 => 4,
            Dtype::U16 => 2,
        }
    }
}

/// One input/output tensor description.
#[derive(Clone, Debug, PartialEq)]
pub struct IoSpec {
    pub name: String,
    pub dtype: Dtype,
    pub shape: Vec<usize>,
}

impl IoSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<IoSpec> {
        Ok(IoSpec {
            name: j.req_str("name")?.to_string(),
            dtype: Dtype::parse(j.req_str("dtype")?)?,
            shape: j
                .req_arr("shape")?
                .iter()
                .map(|s| s.as_usize().ok_or_else(|| anyhow!("bad shape element")))
                .collect::<Result<_>>()?,
        })
    }
}

/// Artifact kind (mirrors aot.py).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Kind {
    LayerOpt,
    LayerBase,
    LayerBcoo,
    ScanOpt,
    LayerToy,
}

impl Kind {
    pub fn parse(s: &str) -> Result<Kind> {
        match s {
            "layer_opt" => Ok(Kind::LayerOpt),
            "layer_base" => Ok(Kind::LayerBase),
            "layer_bcoo" => Ok(Kind::LayerBcoo),
            "scan_opt" => Ok(Kind::ScanOpt),
            "layer_toy" => Ok(Kind::LayerToy),
            _ => bail!("unknown artifact kind {s:?}"),
        }
    }
}

/// One compiled-artifact entry.
#[derive(Clone, Debug)]
pub struct Artifact {
    pub name: String,
    pub path: PathBuf,
    pub kind: Kind,
    pub neurons: usize,
    /// Feature rows the executable processes per dispatch.
    pub capacity: usize,
    pub k: usize,
    pub mb: usize,
    pub tile_n: usize,
    /// Estimated VMEM footprint of one grid step (from KernelConfig).
    pub vmem_bytes: usize,
    /// Fused layer count (scan_opt only).
    pub layers: Option<usize>,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

/// The whole manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub relu_cap: f32,
    pub challenge_bias: BTreeMap<usize, f32>,
    pub artifacts: Vec<Artifact>,
    /// Directory the artifact paths are relative to.
    pub dir: PathBuf,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`?)", path.display()))?;
        Manifest::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: &Path) -> Result<Manifest> {
        let j = Json::parse(text).context("parsing manifest.json")?;
        let version = j.req_usize("version")?;
        if version != 1 {
            bail!("unsupported manifest version {version}");
        }
        let mut challenge_bias = BTreeMap::new();
        if let Some(b) = j.get("challenge_bias").and_then(|b| b.as_obj()) {
            for (k, v) in b {
                challenge_bias.insert(
                    k.parse::<usize>().map_err(|_| anyhow!("bad bias key {k:?}"))?,
                    v.as_f64().ok_or_else(|| anyhow!("bad bias value"))? as f32,
                );
            }
        }
        let mut artifacts = Vec::new();
        for a in j.req_arr("artifacts")? {
            artifacts.push(Artifact {
                name: a.req_str("name")?.to_string(),
                path: dir.join(a.req_str("path")?),
                kind: Kind::parse(a.req_str("kind")?)?,
                neurons: a.req_usize("neurons")?,
                capacity: a.req_usize("capacity")?,
                k: a.req_usize("k")?,
                mb: a.req_usize("mb")?,
                tile_n: a.req_usize("tile_n")?,
                vmem_bytes: a.req_usize("vmem_bytes").unwrap_or(0),
                layers: a.get("layers").and_then(|l| l.as_usize()),
                inputs: a.req_arr("inputs")?.iter().map(IoSpec::from_json).collect::<Result<_>>()?,
                outputs: a
                    .req_arr("outputs")?
                    .iter()
                    .map(IoSpec::from_json)
                    .collect::<Result<_>>()?,
            });
        }
        Ok(Manifest {
            relu_cap: j.req_f64("relu_cap")? as f32,
            challenge_bias,
            artifacts,
            dir: dir.to_path_buf(),
        })
    }

    /// All `layer_opt` capacities available for a width, ascending —
    /// the coordinator's pruning ladder.
    pub fn capacity_ladder(&self, neurons: usize) -> Vec<usize> {
        let mut caps: Vec<usize> = self
            .artifacts
            .iter()
            .filter(|a| a.kind == Kind::LayerOpt && a.neurons == neurons)
            .map(|a| a.capacity)
            .collect();
        caps.sort_unstable();
        caps.dedup();
        caps
    }

    /// Find the `layer_opt` artifact with the given width and capacity.
    pub fn find_layer(&self, kind: Kind, neurons: usize, capacity: usize) -> Option<&Artifact> {
        self.artifacts
            .iter()
            .find(|a| a.kind == kind && a.neurons == neurons && a.capacity == capacity)
    }

    /// Smallest capacity >= `want` for a width (or the largest available).
    pub fn pick_capacity(&self, neurons: usize, want: usize) -> Option<usize> {
        let ladder = self.capacity_ladder(neurons);
        ladder.iter().copied().find(|&c| c >= want).or_else(|| ladder.last().copied())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
 "version": 1,
 "relu_cap": 32.0,
 "challenge_bias": {"1024": -0.3, "4096": -0.35},
 "artifacts": [
  {"name": "layer_opt_n64_c8", "path": "layer_opt_n64_c8.hlo.txt",
   "kind": "layer_opt", "neurons": 64, "capacity": 8, "k": 4, "mb": 4,
   "tile_n": 16, "vmem_bytes": 2048,
   "inputs": [
     {"name": "y", "dtype": "f32", "shape": [8, 64]},
     {"name": "idx", "dtype": "u16", "shape": [64, 4]},
     {"name": "val", "dtype": "f32", "shape": [64, 4]},
     {"name": "bias", "dtype": "f32", "shape": [64]}],
   "outputs": [
     {"name": "y_next", "dtype": "f32", "shape": [8, 64]},
     {"name": "active", "dtype": "i32", "shape": [8]}]},
  {"name": "layer_opt_n64_c32", "path": "layer_opt_n64_c32.hlo.txt",
   "kind": "layer_opt", "neurons": 64, "capacity": 32, "k": 4, "mb": 4,
   "tile_n": 16, "vmem_bytes": 2048, "inputs": [], "outputs": []},
  {"name": "scan_opt_n64_l3_c8", "path": "scan.hlo.txt", "kind": "scan_opt",
   "neurons": 64, "capacity": 8, "k": 4, "mb": 4, "tile_n": 16,
   "vmem_bytes": 0, "layers": 3, "inputs": [], "outputs": []}
 ]
}"#;

    #[test]
    fn parse_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.relu_cap, 32.0);
        assert_eq!(m.challenge_bias[&1024], -0.3);
        assert_eq!(m.artifacts.len(), 3);
        let a = &m.artifacts[0];
        assert_eq!(a.kind, Kind::LayerOpt);
        assert_eq!(a.inputs[1].dtype, Dtype::U16);
        assert_eq!(a.inputs[0].elements(), 8 * 64);
        assert_eq!(a.path, Path::new("/tmp/a/layer_opt_n64_c8.hlo.txt"));
        assert_eq!(m.artifacts[2].layers, Some(3));
    }

    #[test]
    fn ladder_and_pick() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp")).unwrap();
        assert_eq!(m.capacity_ladder(64), vec![8, 32]);
        assert_eq!(m.pick_capacity(64, 1), Some(8));
        assert_eq!(m.pick_capacity(64, 8), Some(8));
        assert_eq!(m.pick_capacity(64, 9), Some(32));
        assert_eq!(m.pick_capacity(64, 99), Some(32), "clamps to largest");
        assert_eq!(m.pick_capacity(128, 1), None);
        assert!(m.find_layer(Kind::LayerOpt, 64, 8).is_some());
        assert!(m.find_layer(Kind::LayerBase, 64, 8).is_none());
    }

    #[test]
    fn rejects_bad_version_and_kind() {
        let bad = SAMPLE.replace("\"version\": 1", "\"version\": 9");
        assert!(Manifest::parse(&bad, Path::new("/tmp")).is_err());
        let bad = SAMPLE.replace("layer_opt\"", "layer_wat\"");
        assert!(Manifest::parse(&bad, Path::new("/tmp")).is_err());
        assert!(Dtype::parse("f64").is_err());
    }
}
