//! Runtime: PJRT artifact loading/execution (`pjrt`), the artifact
//! manifest (`manifest`) and out-of-core weight streaming (`streamer`).
//!
//! Python runs only at build time; this module is how the Rust
//! coordinator executes the AOT-compiled L1/L2 computations.

pub mod manifest;
pub mod pjrt;
pub mod streamer;

pub use manifest::{Artifact, Kind, Manifest};
pub use pjrt::{CompiledLayer, LayerLiterals, LayerOut, PjrtBackend};
pub use streamer::WeightStreamer;

use std::path::PathBuf;

/// Default artifacts directory: `$SPDNN_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("SPDNN_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}
