//! Cross-engine equivalence: `CsrEngine`, `EllEngine` and
//! `SlicedEllEngine` must produce *bit-identical* outputs over randomized
//! RadixNet-style topologies, batch sizes (including non-multiples of the
//! minibatch), minibatch widths, slice granularities and thread counts.
//!
//! Bit-identity holds because all three engines accumulate each output in
//! the same per-row entry order (CSR order, which ELL packing and sliced
//! transposition both preserve) and fuse the same `relu_clip(acc + bias)`
//! epilogue; threading splits features, never a single accumulation.

use spdnn::engine::{CsrEngine, EllEngine, SlicedEllEngine};
use spdnn::formats::convert::ell_to_csr;
use spdnn::formats::{EllMatrix, SlicedEll};
use spdnn::radixnet::{RadixNet, Topology};
use spdnn::util::prng::Xoshiro256;
use spdnn::util::proptest::{self, Runner};

fn random_problem(
    rng: &mut Xoshiro256,
    n: usize,
    k: usize,
    batch: usize,
    topology: Topology,
) -> (EllMatrix, Vec<f32>, Vec<f32>) {
    let net = RadixNet::new(n, 1, k, topology, rng.next_u64()).unwrap();
    let mut w = net.layer_ell(0);
    for v in w.value.iter_mut() {
        *v = rng.next_range_f32(-0.5, 0.5);
    }
    let bias: Vec<f32> = (0..n).map(|_| rng.next_range_f32(-0.3, 0.1)).collect();
    let y = proptest::sparse_binary(rng, batch * n, 0.3);
    (w, bias, y)
}

#[test]
fn all_engines_bit_identical_randomized() {
    Runner::new(48, 0xEC0).run("engine-equivalence", |rng| {
        let n = *proptest::choose(rng, &[16usize, 32, 64, 128]);
        let k = proptest::usize_in(rng, 1, 8.min(n));
        // Deliberately spans batches that are NOT multiples of mb.
        let batch = proptest::usize_in(rng, 1, 37);
        let mb = *proptest::choose(rng, &[1usize, 5, 12, 64]);
        let slice = *proptest::choose(rng, &[1usize, 2, 7, 16, 32]);
        let threads = *proptest::choose(rng, &[1usize, 2, 3]);
        let topology =
            if rng.next_f32() < 0.5 { Topology::Butterfly } else { Topology::Random };
        let (w, bias, y) = random_problem(rng, n, k, batch, topology);
        let csr = ell_to_csr(&w).unwrap();
        let sliced = SlicedEll::from_ell(&w, slice).unwrap();

        let mut want = vec![0.0f32; y.len()];
        CsrEngine.layer(&csr, &bias, &y, &mut want);

        let mut got_ell = vec![0.0f32; y.len()];
        EllEngine::with_mb(threads, mb)
            .unwrap()
            .layer(&w, &bias, &y, &mut got_ell);
        if got_ell != want {
            return Err(format!(
                "ell != csr (n={n} k={k} batch={batch} mb={mb} threads={threads})"
            ));
        }

        let mut got_sliced = vec![0.0f32; y.len()];
        SlicedEllEngine::with_mb(threads, mb)
            .unwrap()
            .layer(&sliced, &bias, &y, &mut got_sliced);
        if got_sliced != want {
            return Err(format!(
                "sliced != csr (n={n} k={k} batch={batch} mb={mb} slice={slice} threads={threads})"
            ));
        }
        Ok(())
    });
}

#[test]
fn multi_layer_network_stays_bit_identical() {
    // A deeper composition: errors would compound across layers if any
    // engine diverged even in the last bit.
    let mut rng = Xoshiro256::new(0xD0E);
    let n = 64usize;
    let k = 6usize;
    let batch = 23usize; // not a multiple of 12
    let layers = 8usize;
    let net = RadixNet::new(n, layers, k, Topology::Random, 99).unwrap();
    let weights: Vec<EllMatrix> = (0..layers)
        .map(|l| {
            let mut w = net.layer_ell(l);
            for v in w.value.iter_mut() {
                *v = rng.next_range_f32(-0.4, 0.4);
            }
            w
        })
        .collect();
    let bias: Vec<f32> = (0..n).map(|_| rng.next_range_f32(-0.2, 0.05)).collect();
    let y0 = proptest::sparse_binary(&mut rng, batch * n, 0.4);

    let run_csr = |y0: &[f32]| {
        let mut y = y0.to_vec();
        let mut scratch = vec![0.0f32; y.len()];
        for w in &weights {
            let csr = ell_to_csr(w).unwrap();
            CsrEngine.layer(&csr, &bias, &y, &mut scratch);
            std::mem::swap(&mut y, &mut scratch);
        }
        y
    };
    let run_ell = |y0: &[f32], mb: usize, threads: usize| {
        let engine = EllEngine::with_mb(threads, mb).unwrap();
        let mut y = y0.to_vec();
        let mut scratch = vec![0.0f32; y.len()];
        for w in &weights {
            engine.layer(w, &bias, &y, &mut scratch);
            std::mem::swap(&mut y, &mut scratch);
        }
        y
    };
    let run_sliced = |y0: &[f32], mb: usize, slice: usize, threads: usize| {
        let engine = SlicedEllEngine::with_mb(threads, mb).unwrap();
        let mut y = y0.to_vec();
        let mut scratch = vec![0.0f32; y.len()];
        for w in &weights {
            let s = SlicedEll::from_ell(w, slice).unwrap();
            engine.layer(&s, &bias, &y, &mut scratch);
            std::mem::swap(&mut y, &mut scratch);
        }
        y
    };

    let want = run_csr(&y0);
    for mb in [1usize, 5, 12] {
        for threads in [1usize, 4] {
            assert_eq!(run_ell(&y0, mb, threads), want, "ell mb={mb} threads={threads}");
            for slice in [1usize, 8, 32, 64] {
                assert_eq!(
                    run_sliced(&y0, mb, slice, threads),
                    want,
                    "sliced mb={mb} slice={slice} threads={threads}"
                );
            }
        }
    }
}

#[test]
fn empty_and_single_feature_batches() {
    let mut rng = Xoshiro256::new(0xD0F);
    let (w, bias, _) = random_problem(&mut rng, 32, 4, 1, Topology::Butterfly);
    let csr = ell_to_csr(&w).unwrap();
    let sliced = SlicedEll::from_ell(&w, 8).unwrap();

    // Empty batch: all engines accept a zero-length panel.
    let empty: Vec<f32> = vec![];
    let mut out: Vec<f32> = vec![];
    CsrEngine.layer(&csr, &bias, &empty, &mut out);
    EllEngine::new(2).layer(&w, &bias, &empty, &mut out);
    SlicedEllEngine::new(2).layer(&sliced, &bias, &empty, &mut out);

    // Single feature: threads clamp down to the batch.
    let y = proptest::sparse_binary(&mut rng, 32, 0.5);
    let mut a = vec![0.0f32; 32];
    let mut b = vec![0.0f32; 32];
    let mut c = vec![0.0f32; 32];
    CsrEngine.layer(&csr, &bias, &y, &mut a);
    EllEngine::new(8).layer(&w, &bias, &y, &mut b);
    SlicedEllEngine::new(8).layer(&sliced, &bias, &y, &mut c);
    assert_eq!(a, b);
    assert_eq!(a, c);
}
