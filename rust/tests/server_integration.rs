//! Loopback integration for the `spdnn::server` subsystem: a real TCP
//! server on port 0 driven through the JSON-lines protocol — replica
//! sharding, load shedding under a saturating burst, per-request
//! deadlines and graceful drain.

use std::sync::{Arc, Barrier};
use std::time::Duration;

use spdnn::coordinator::batcher::{BatchPolicy, ServeBackend, ServedModel};
use spdnn::data::Dataset;
use spdnn::server::{
    AdmissionConfig, Client, InferInput, InferRequest, ReferencePanel, Request, Server,
    ServerConfig, ServerHandle, WireResponse,
};
use spdnn::util::config::RuntimeConfig;

const NEURONS: usize = 64;

fn model() -> (ServedModel, Dataset) {
    let cfg = RuntimeConfig { neurons: NEURONS, layers: 4, k: 4, batch: 8, ..Default::default() };
    let ds = Dataset::generate(&cfg).unwrap();
    (ServedModel::from_dataset(&ds), ds)
}

fn native() -> ServeBackend {
    ServeBackend::native(1, 12)
}

fn start(cfg: ServerConfig) -> (ServerHandle, Dataset) {
    let (m, ds) = model();
    let reference = ReferencePanel { features: ds.features.clone(), neurons: NEURONS };
    let handle = Server::start(cfg, m, native(), Some(reference)).unwrap();
    (handle, ds)
}

#[test]
fn loopback_roundtrip_and_replica_sharding() {
    let (handle, ds) = start(ServerConfig {
        replicas: 2,
        policy: BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
        ..Default::default()
    });
    let mut client = Client::connect(handle.addr()).unwrap();

    assert_eq!(client.call(&Request::Ping).unwrap(), WireResponse::Pong);

    // Two passes over the reference rows: answers must match the offline
    // ground truth, and sequential requests must hit both replicas
    // (interleaved routing: consecutive requests alternate replicas).
    let mut row0_active = None;
    for pass in 0..2 {
        for i in 0..ds.cfg.batch {
            match client.call(&Request::infer_row(i)).unwrap() {
                WireResponse::Infer { active, activations, batch_size, latency_ms, .. } => {
                    assert_eq!(
                        active,
                        ds.truth_categories.contains(&i),
                        "pass {pass} row {i}"
                    );
                    assert_eq!(activations.expect("activations included").len(), NEURONS);
                    assert!(batch_size >= 1);
                    assert!(latency_ms >= 0.0);
                    if i == 0 {
                        row0_active = Some(active);
                    }
                }
                other => panic!("expected infer response, got {other:?}"),
            }
        }
    }

    // The same row sent as an explicit feature vector agrees.
    let feats = ds.features[..NEURONS].to_vec();
    match client.call(&Request::infer_features(feats)).unwrap() {
        WireResponse::Infer { active, .. } => assert_eq!(Some(active), row0_active),
        other => panic!("expected infer response, got {other:?}"),
    }

    // Router sharding observed: both replicas routed work.
    match client.call(&Request::Stats).unwrap() {
        WireResponse::Stats(stats) => {
            let replicas = stats.req_arr("replicas").unwrap();
            assert_eq!(replicas.len(), 2);
            let routed: Vec<usize> =
                replicas.iter().map(|r| r.req_usize("routed").unwrap()).collect();
            assert!(
                routed.iter().all(|&c| c > 0),
                "both replicas must receive work: {routed:?}"
            );
            assert_eq!(routed.iter().sum::<usize>(), 17);
            assert!(stats.req_f64("imbalance").unwrap() >= 1.0);
            assert_eq!(stats.req_usize("shed").unwrap(), 0);
            assert!(stats.get("latency_ms").unwrap().req_f64("p95").is_ok());
        }
        other => panic!("expected stats response, got {other:?}"),
    }

    let report = handle.shutdown();
    assert!(report.drained, "all in-flight work answered");
    assert_eq!(report.requests, 17);
    assert_eq!(report.errors, 0);
    assert!(report.workers_clean, "in-process serving has no workers to reap");
}

#[test]
fn saturating_burst_sheds_load_then_recovers() {
    // One slow replica: the batcher holds its panel open for 100ms, so a
    // 12-request burst against a 2-deep queue must shed most of it.
    let (handle, _ds) = start(ServerConfig {
        replicas: 1,
        policy: BatchPolicy { max_batch: 64, max_wait: Duration::from_millis(100) },
        admission: AdmissionConfig {
            queue_cap: 2,
            deadline: Duration::from_secs(10),
            initial_estimate: Duration::from_micros(1),
            ..Default::default()
        },
        ..Default::default()
    });
    let addr = handle.addr();

    let burst = 12;
    let barrier = Arc::new(Barrier::new(burst));
    let mut oks = 0usize;
    let mut sheds = 0usize;
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..burst)
            .map(|_| {
                let barrier = barrier.clone();
                scope.spawn(move || {
                    let mut client = Client::connect(addr).unwrap();
                    barrier.wait();
                    client.call(&Request::infer_features(vec![1.0; NEURONS])).unwrap()
                })
            })
            .collect();
        for h in handles {
            match h.join().expect("burst client") {
                WireResponse::Infer { .. } => oks += 1,
                WireResponse::Shed { reason, retry_after_ms } => {
                    assert_eq!(reason, "queue full");
                    assert!(retry_after_ms > 0.0, "retry hint must be positive");
                    sheds += 1;
                }
                other => panic!("unexpected burst response: {other:?}"),
            }
        }
    });
    assert!(oks >= 1, "some of the burst must be admitted (oks={oks})");
    assert!(sheds >= 1, "a 2-deep queue cannot absorb a 12-request burst (sheds={sheds})");
    assert_eq!(oks + sheds, burst);

    // After the burst drains the server accepts work again.
    let mut client = Client::connect(addr).unwrap();
    assert!(matches!(
        client.call(&Request::infer_features(vec![0.5; NEURONS])).unwrap(),
        WireResponse::Infer { .. }
    ));

    let report = handle.shutdown();
    assert!(report.drained);
    assert_eq!(report.shed as usize, sheds);
}

#[test]
fn per_request_deadline_is_enforced() {
    // The batcher holds panels open for 200ms; a 1ms-deadline request is
    // admitted (predicted wait ~0.5ms) but must come back as a deadline
    // error instead of waiting for the panel.
    let (handle, ds) = start(ServerConfig {
        replicas: 1,
        policy: BatchPolicy { max_batch: 64, max_wait: Duration::from_millis(200) },
        ..Default::default()
    });
    let mut client = Client::connect(handle.addr()).unwrap();
    let resp = client
        .call(&Request::Infer(InferRequest {
            input: InferInput::Features(ds.features[..NEURONS].to_vec()),
            deadline_ms: Some(1.0),
            want_activations: true,
            trace: None,
        }))
        .unwrap();
    match resp {
        WireResponse::Error { message } => {
            assert!(message.contains("deadline exceeded"), "{message}");
        }
        other => panic!("expected a deadline error, got {other:?}"),
    }
    let report = handle.shutdown();
    assert_eq!(report.errors, 1);
}

#[test]
fn epoch_edge_deadlines_are_clamped_and_answered_not_panicked() {
    // The deadline math at the epoch edge: a 0-ms deadline is admitted
    // against an empty queue (the predicted wait is exactly zero) and
    // must come back as a clean deadline error — never a panic, never a
    // hang. A negative deadline clamps to zero and behaves identically.
    let (handle, ds) = start(ServerConfig {
        replicas: 1,
        policy: BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
        ..Default::default()
    });
    let mut client = Client::connect(handle.addr()).unwrap();
    for dl in [0.0, -5.0] {
        let resp = client
            .call(&Request::Infer(InferRequest {
                input: InferInput::Features(ds.features[..NEURONS].to_vec()),
                deadline_ms: Some(dl),
                want_activations: false,
                trace: None,
            }))
            .unwrap();
        match resp {
            WireResponse::Error { message } => {
                assert!(
                    message.contains("deadline exceeded after 0.0ms"),
                    "deadline_ms={dl}: {message}"
                );
            }
            other => panic!("deadline_ms={dl}: expected a deadline error, got {other:?}"),
        }
    }
    // The abandoned slots are reaped once their panels complete; normal
    // traffic flows immediately after.
    assert!(matches!(
        client.call(&Request::infer_features(ds.features[..NEURONS].to_vec())).unwrap(),
        WireResponse::Infer { .. }
    ));
    let report = handle.shutdown();
    assert_eq!(report.errors, 2);
    assert!(report.drained);
}

#[test]
fn deadline_shorter_than_backend_service_time_is_shed_once_queued() {
    // A deadline below one backend service time (the cluster analog:
    // shorter than one scatter RTT) is only meetable from an empty
    // queue. Occupy the queue with a slow panel, and the tight-deadline
    // request must be shed up front with the deadline reason — not
    // admitted into guaranteed lateness.
    let (handle, _ds) = start(ServerConfig {
        replicas: 1,
        policy: BatchPolicy { max_batch: 64, max_wait: Duration::from_millis(300) },
        admission: AdmissionConfig {
            queue_cap: 16,
            deadline: Duration::from_secs(10),
            // Pretend the backend needs 200ms per request: any queued
            // work predicts a >=200ms wait.
            initial_estimate: Duration::from_millis(200),
            concurrency: 1,
        },
        ..Default::default()
    });
    let addr = handle.addr();
    // Occupy one queue slot (its panel stays open for 300ms).
    let holder = std::thread::spawn(move || {
        let mut c = Client::connect(addr).unwrap();
        c.call(&Request::infer_features(vec![1.0; NEURONS])).unwrap()
    });
    std::thread::sleep(Duration::from_millis(50));
    let mut client = Client::connect(addr).unwrap();
    let resp = client
        .call(&Request::Infer(InferRequest {
            input: InferInput::Features(vec![0.5; NEURONS]),
            deadline_ms: Some(20.0), // < one 200ms service time
            want_activations: false,
            trace: None,
        }))
        .unwrap();
    match resp {
        WireResponse::Shed { reason, retry_after_ms } => {
            assert_eq!(reason, "deadline unmeetable");
            assert!(retry_after_ms > 0.0);
        }
        other => panic!("expected a deadline shed, got {other:?}"),
    }
    assert!(matches!(holder.join().unwrap(), WireResponse::Infer { .. }));
    let report = handle.shutdown();
    assert_eq!(report.shed, 1);
}

#[test]
fn malformed_and_invalid_requests_get_clean_errors() {
    let (handle, _ds) = start(ServerConfig {
        replicas: 1,
        policy: BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
        ..Default::default()
    });
    let mut client = Client::connect(handle.addr()).unwrap();

    // Wrong feature width propagates the batcher's validation error.
    match client.call(&Request::infer_features(vec![0.0; 3])).unwrap() {
        WireResponse::Error { message } => assert!(message.contains("expects"), "{message}"),
        other => panic!("expected error, got {other:?}"),
    }
    // Reference row out of range.
    match client.call(&Request::infer_row(999)).unwrap() {
        WireResponse::Error { message } => assert!(message.contains("out of range"), "{message}"),
        other => panic!("expected error, got {other:?}"),
    }
    // Opting out of activations trims the response.
    match client
        .call(&Request::Infer(InferRequest {
            input: InferInput::Row(0),
            deadline_ms: None,
            want_activations: false,
            trace: None,
        }))
        .unwrap()
    {
        WireResponse::Infer { activations, .. } => assert!(activations.is_none()),
        other => panic!("expected infer response, got {other:?}"),
    }
    handle.shutdown();
}

#[test]
fn remote_drain_rejects_new_work_and_stops_cleanly() {
    let (handle, _ds) = start(ServerConfig {
        replicas: 2,
        policy: BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
        ..Default::default()
    });
    let addr = handle.addr();
    let mut client = Client::connect(addr).unwrap();

    assert!(matches!(
        client.call(&Request::infer_features(vec![1.0; NEURONS])).unwrap(),
        WireResponse::Infer { .. }
    ));

    // Remote graceful shutdown over the wire.
    assert_eq!(client.call(&Request::Shutdown).unwrap(), WireResponse::Draining);

    // New work on the existing connection is rejected as draining (or the
    // connection is already closed if the poll loop won the race).
    match client.call(&Request::infer_features(vec![1.0; NEURONS])) {
        Ok(WireResponse::Shed { reason, .. }) => assert_eq!(reason, "draining"),
        Ok(other) => panic!("expected a draining shed, got {other:?}"),
        Err(_) => {} // server side already closed — also a valid rejection
    }

    // wait() returns because the client-triggered stop halted the accept
    // loop; the drain must be clean.
    let report = handle.wait();
    assert!(report.drained);
    assert!(report.requests >= 1);

    // The listener is gone: fresh connections fail outright or die on
    // first use.
    match Client::connect(addr) {
        Err(_) => {}
        Ok(mut c) => assert!(c.call(&Request::Ping).is_err()),
    }
}
