//! ISSUE 6 acceptance: end-to-end observability across the serving and
//! cluster tiers.
//!
//! * A `serve --ranks 2` request produces a single stitched trace: one
//!   TraceId spans admission -> batcher -> per-rank scatter/compute ->
//!   reply, and the exported Chrome trace-event JSON contains spans
//!   from both worker-rank OS processes under that TraceId.
//! * The `{"op":"metrics"}` snapshot passes the same exposition check
//!   `spdnn check-metrics` applies in CI.
//!
//! The span recorder is process-global, so everything that toggles it
//! lives in this one test function (integration tests in other files
//! run in their own processes).

use std::path::PathBuf;
use std::time::Duration;

use spdnn::cluster::ModelSpec;
use spdnn::coordinator::batcher::BatchPolicy;
use spdnn::coordinator::NativeSpec;
use spdnn::data::Dataset;
use spdnn::engine::EngineKind;
use spdnn::obs::metrics::validate_exposition;
use spdnn::obs::trace::chrome_events;
use spdnn::server::{
    Client, ClusterServeConfig, InferInput, InferRequest, ReferencePanel, Request, Server,
    ServerConfig, WireResponse,
};
use spdnn::util::config::RuntimeConfig;
use spdnn::util::json::Json;

const NEURONS: usize = 64;

fn program() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_spdnn"))
}

#[test]
fn traced_request_stitches_across_both_rank_processes() {
    let cfg = RuntimeConfig { neurons: NEURONS, layers: 5, k: 4, batch: 12, ..Default::default() };
    let ds = Dataset::generate(&cfg).unwrap();
    let trace_path =
        std::env::temp_dir().join(format!("spdnn_obs_trace_{}.json", std::process::id()));

    // One replica owning both ranks: every panel scatters across the
    // two worker processes, so a single request's trace must contain
    // spans from both.
    let server_cfg = ServerConfig {
        replicas: 1,
        policy: BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
        trace_out: Some(trace_path.clone()),
        ..Default::default()
    };
    let spec = NativeSpec { engine: EngineKind::Sliced, minibatch: 12, slice: 16, threads: 1 };
    let ccfg = ClusterServeConfig::local(program(), 2);
    let reference = ReferencePanel { features: ds.features.clone(), neurons: NEURONS };
    let handle = Server::start_cluster(
        server_cfg,
        &ccfg,
        &ModelSpec::from_config(&cfg),
        spec,
        cfg.prune,
        Some(reference),
    )
    .unwrap();

    let mut client = Client::connect(handle.addr()).unwrap();
    // Pin the TraceId so the assertion below knows what to look for;
    // the response must echo it back.
    let pinned = "00000000c0ffee01";
    let resp = client
        .call(&Request::Infer(InferRequest {
            input: InferInput::Row(0),
            deadline_ms: None,
            want_activations: false,
            trace: Some(pinned.to_string()),
        }))
        .unwrap();
    match resp {
        WireResponse::Infer { trace, .. } => assert_eq!(trace, pinned, "response echoes the id"),
        other => panic!("expected infer response, got {other:?}"),
    }
    // A second, server-minted trace id must also round-trip.
    let minted = match client.call(&Request::infer_row(1)).unwrap() {
        WireResponse::Infer { trace, .. } => trace,
        other => panic!("expected infer response, got {other:?}"),
    };
    assert_eq!(minted.len(), 16, "server mints a 16-hex-digit id, got {minted:?}");
    assert_ne!(minted, pinned);

    // The metrics verb returns a snapshot that passes the exposition
    // validation `spdnn check-metrics` gates on.
    let text = match client.call(&Request::Metrics).unwrap() {
        WireResponse::Metrics { text } => text,
        other => panic!("expected metrics response, got {other:?}"),
    };
    let summary = validate_exposition(&text).expect("metrics must validate");
    assert!(summary.families > 0 && summary.samples > 0);
    assert!(text.contains("spdnn_serve_requests_total"), "serve counters present:\n{text}");
    assert!(text.contains("spdnn_cluster_scatter_bytes_total"), "cluster counters present");

    // Shutdown writes the Chrome trace.
    let report = handle.shutdown();
    assert!(report.drained);
    assert_eq!(report.errors, 0);

    let doc = Json::parse(&std::fs::read_to_string(&trace_path).unwrap()).unwrap();
    let events = chrome_events(&doc).unwrap();
    let traced: Vec<&Json> = events
        .iter()
        .filter(|e| {
            e.req("args")
                .ok()
                .and_then(|a| a.get("trace"))
                .and_then(|t| t.as_str())
                .map(|t| t == pinned)
                .unwrap_or(false)
        })
        .collect();
    let names: Vec<&str> =
        traced.iter().filter_map(|e| e.req("name").ok().and_then(|n| n.as_str())).collect();
    // Admission -> batcher -> coordinator scatter -> rank compute, all
    // under the one pinned TraceId.
    for want in ["request", "cluster-pass", "shard-rpc", "rank-compute"] {
        assert!(names.contains(&want), "span {want:?} missing from {names:?}");
    }
    // Spans from BOTH rank processes: lanes (chrome pids) 1 and 2 are
    // rank 0 and rank 1; lane 0 is the server process.
    let lanes: Vec<i64> =
        traced.iter().filter_map(|e| e.req("pid").ok().and_then(|p| p.as_i64())).collect();
    for lane in [0i64, 1, 2] {
        assert!(lanes.contains(&lane), "no spans on lane {lane} (lanes seen: {lanes:?})");
    }
    std::fs::remove_file(&trace_path).ok();
}
