//! Cluster-backed serving end to end: `Server::start_cluster` routing
//! real TCP requests onto real worker-rank OS processes, proven under
//! fault injection via the reusable chaos proxy (`common::chaos`).
//!
//! The acceptance bar of ISSUE 5:
//! * responses from a `--ranks 2` server are bit-identical to
//!   single-process serving on the sliced engine;
//! * a stalled rank produces deadline errors + sheds with exact
//!   `/stats` accounting, and the server recovers when the stall ends;
//! * a rank killed mid-request lame-ducks its replica, stragglers are
//!   salvaged onto a live replica (counted in `/stats.rerouted`), and
//!   the drain is clean — without the server process ever exiting;
//! * with `--heal`, a killed rank is respawned, the recipe re-shipped,
//!   and the healed replica answers bit-identically (flight order:
//!   rank-death < lame-duck < replica-healed); `--heal off` keeps the
//!   historical lame-forever contract;
//! * the background ping sweep lame-ducks an adopted rank whose
//!   connection was severed, with no inference traffic flowing;
//! * wire-negotiation downgrade: a v1-era json-only peer behind the
//!   chaos proxy settles on json with no frames lost (property test
//!   over randomized payloads, chunking and arrival jitter);
//! * weight-sharded serving (`--partition weights`) survives a severed
//!   exchange frame mid-layer: clean error, lame replica, live server;
//! * the flight recorder captures a chaos rank kill as rank-death
//!   strictly before lame-duck (by sequence number), and
//!   `{"op":"health"}` downgrades to `degraded` naming the casualty.

mod common;

use std::net::TcpListener;
use std::path::PathBuf;
use std::sync::mpsc;
use std::time::Duration;

use common::chaos::{ChaosProxy, Fault};
use spdnn::cluster::transport::{read_request, write_reply, ReadOutcome};
use spdnn::cluster::{
    ClusterClient, ClusterOptions, ClusterReply, ClusterRequest, HealPolicy, Launcher,
    LauncherConfig, ModelSpec, PartitionScheme, ShardResult, WireFormat, CONTROL_FRAME_CAP,
};
use spdnn::coordinator::batcher::{BatchPolicy, ServeBackend, ServedModel};
use spdnn::coordinator::NativeSpec;
use spdnn::data::Dataset;
use spdnn::engine::EngineKind;
use spdnn::obs::flight;
use spdnn::obs::TraceId;
use spdnn::server::{
    AdmissionConfig, Client, ClusterServeConfig, InferInput, InferRequest, ReferencePanel,
    Request, Server, ServerConfig, ServerHandle, WireResponse,
};
use spdnn::util::config::RuntimeConfig;
use spdnn::util::json::Json;
use spdnn::util::proptest::{self, Runner};

const NEURONS: usize = 64;

fn program() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_spdnn"))
}

fn small_cfg() -> RuntimeConfig {
    RuntimeConfig { neurons: NEURONS, layers: 5, k: 4, batch: 12, ..Default::default() }
}

fn sliced_spec() -> NativeSpec {
    NativeSpec { engine: EngineKind::Sliced, minibatch: 12, slice: 16, threads: 1 }
}

fn server_cfg(replicas: usize) -> ServerConfig {
    ServerConfig {
        replicas,
        policy: BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
        ..Default::default()
    }
}

fn start_cluster_server(
    cfg: ServerConfig,
    ds: &Dataset,
    ccfg: &ClusterServeConfig,
) -> ServerHandle {
    let model = ModelSpec::from_config(&ds.cfg);
    let reference = ReferencePanel { features: ds.features.clone(), neurons: ds.cfg.neurons };
    Server::start_cluster(cfg, ccfg, &model, sliced_spec(), ds.cfg.prune, Some(reference))
        .expect("cluster server start")
}

fn infer_ok(client: &mut Client, req: &Request) -> (bool, Option<Vec<f32>>) {
    match client.call(req).expect("wire call") {
        WireResponse::Infer { active, activations, .. } => (active, activations),
        other => panic!("expected infer response, got {other:?}"),
    }
}

fn stats(client: &mut Client) -> Json {
    match client.call(&Request::Stats).expect("stats call") {
        WireResponse::Stats(s) => s,
        other => panic!("expected stats response, got {other:?}"),
    }
}

/// Acceptance: the same requests against `serve --ranks 2` and a
/// single-process sliced-engine server answer with identical activity
/// flags and bit-identical activations.
#[test]
fn cluster_serving_is_bit_identical_to_in_process_sliced_serving() {
    let cfg = small_cfg();
    let ds = Dataset::generate(&cfg).unwrap();

    let oracle = Server::start(
        server_cfg(2),
        ServedModel::from_dataset(&ds),
        ServeBackend::Native { spec: sliced_spec() },
        Some(ReferencePanel { features: ds.features.clone(), neurons: NEURONS }),
    )
    .unwrap();
    let ccfg = ClusterServeConfig::local(program(), 2);
    let clustered = start_cluster_server(server_cfg(2), &ds, &ccfg);
    assert!(clustered.is_cluster());
    assert!(!oracle.is_cluster());

    let mut a = Client::connect(oracle.addr()).unwrap();
    let mut b = Client::connect(clustered.addr()).unwrap();
    for pass in 0..2 {
        for i in 0..cfg.batch {
            let (want_active, want_acts) = infer_ok(&mut a, &Request::infer_row(i));
            let (got_active, got_acts) = infer_ok(&mut b, &Request::infer_row(i));
            assert_eq!(want_active, ds.truth_categories.contains(&i), "oracle sanity row {i}");
            assert_eq!(got_active, want_active, "pass {pass} row {i}");
            let want_acts = want_acts.expect("oracle activations");
            let got_acts = got_acts.expect("cluster activations");
            assert_eq!(got_acts.len(), want_acts.len(), "pass {pass} row {i}");
            for (j, (x, y)) in got_acts.iter().zip(&want_acts).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "pass {pass} row {i} value {j}: {x} != {y}");
            }
        }
    }
    // An explicit feature vector takes the same path.
    let feats = ds.features[..NEURONS].to_vec();
    let (want_active, _) = infer_ok(&mut a, &Request::infer_features(feats.clone()));
    let (got_active, _) = infer_ok(&mut b, &Request::infer_features(feats));
    assert_eq!(got_active, want_active);

    // Both replicas of the cluster server saw work, and its /stats
    // carries the per-rank wire counters.
    let snap = stats(&mut b);
    assert!(snap.req("cluster").unwrap().as_bool().unwrap());
    let replicas = snap.req_arr("replicas").unwrap();
    assert_eq!(replicas.len(), 2);
    for r in replicas {
        assert!(r.req_usize("routed").unwrap() > 0, "both replicas must see work");
        let ranks = r.req_arr("ranks").unwrap();
        assert_eq!(ranks.len(), 1, "2 ranks over 2 replicas: one each");
        assert!(ranks[0].req("alive").unwrap().as_bool().unwrap());
        assert!(ranks[0].req_usize("scatter_bytes").unwrap() > 0);
        assert!(ranks[0].req_usize("gather_bytes").unwrap() > 0);
    }

    let ra = oracle.shutdown();
    assert!(ra.drained);
    let rb = clustered.shutdown();
    assert!(rb.drained, "cluster drain must answer everything");
    assert!(rb.workers_clean, "worker ranks must exit cleanly after the fenced shutdown");
    assert_eq!(rb.errors, 0);
}

/// Acceptance: a stalled (not dead) rank. Requests against its replica
/// exceed their deadlines; the occupied queue slot sheds the traffic
/// behind it with exact accounting; nobody is lame (a stall is not a
/// death) and the server recovers the moment the stall clears.
#[test]
fn stalled_rank_sheds_and_deadline_errors_with_correct_accounting() {
    let cfg = small_cfg();
    let ds = Dataset::generate(&cfg).unwrap();
    let mut launcher = Launcher::spawn(&LauncherConfig::local(program(), 2)).unwrap();
    let worker_addrs = launcher.addrs();
    let proxy = ChaosProxy::start(worker_addrs[0]);
    let ccfg = ClusterServeConfig {
        addrs: Some(vec![proxy.addr(), worker_addrs[1]]),
        ..ClusterServeConfig::local(program(), 2)
    };
    let mut scfg = server_cfg(2);
    // One queue slot: the stalled request's held slot must shed
    // everything behind it, deterministically.
    scfg.admission = AdmissionConfig { queue_cap: 1, ..Default::default() };
    let handle = start_cluster_server(scfg, &ds, &ccfg);
    let mut client = Client::connect(handle.addr()).unwrap();

    // Healthy pass through both replicas (seq 0 -> replica 0, 1 -> 1).
    for i in 0..2 {
        let (active, _) = infer_ok(&mut client, &Request::infer_row(i));
        assert_eq!(active, ds.truth_categories.contains(&i), "healthy row {i}");
    }

    // Stall rank 0's request path: bytes still flow, just 1.5s late.
    let stall = Duration::from_millis(1500);
    proxy.set_fault(Fault::Delay { after: proxy.messages(), delay: stall });

    // seq 2 -> replica 0: admitted (queue empty), then the 100ms
    // deadline fires long before the stalled scatter answers.
    let resp = client
        .call(&Request::Infer(InferRequest {
            input: InferInput::Row(0),
            deadline_ms: Some(100.0),
            want_activations: false,
            trace: None,
        }))
        .unwrap();
    match resp {
        WireResponse::Error { message } => {
            assert!(message.contains("deadline exceeded"), "unexpected error: {message}");
        }
        other => panic!("expected a deadline error, got {other:?}"),
    }

    // The timed-out request still occupies its queue slot (the batcher
    // holds it until the stalled panel completes): a 1-deep queue now
    // sheds everything.
    for i in 0..3 {
        match client.call(&Request::infer_row(1)).unwrap() {
            WireResponse::Shed { reason, retry_after_ms } => {
                assert_eq!(reason, "queue full", "shed {i}");
                assert!(retry_after_ms > 0.0, "shed {i}");
            }
            other => panic!("expected a queue-full shed, got {other:?}"),
        }
    }

    // Exact accounting while the stall is still in progress.
    let snap = stats(&mut client);
    assert_eq!(snap.req_usize("shed").unwrap(), 3);
    assert_eq!(snap.req_usize("errors").unwrap(), 1);
    assert_eq!(snap.req_usize("queue_depth").unwrap(), 1, "the reaped slot is still held");
    assert!(snap.req("cluster").unwrap().as_bool().unwrap());
    assert_eq!(snap.req_usize("live_replicas").unwrap(), 2, "a stall is not a death");
    assert!(snap.get("latency_ms").unwrap().req_f64("p95").is_ok());
    for r in snap.req_arr("replicas").unwrap() {
        assert!(!r.req("lame").unwrap().as_bool().unwrap());
        let ranks = r.req_arr("ranks").unwrap();
        assert!(ranks[0].req("alive").unwrap().as_bool().unwrap());
    }

    // Clear the stall; once the in-flight panel drains the slot frees
    // and both replicas serve again.
    proxy.set_fault(Fault::None);
    std::thread::sleep(stall + Duration::from_millis(1500));
    for _ in 0..2 {
        let (_, acts) = infer_ok(&mut client, &Request::infer_row(0));
        assert!(acts.is_some());
    }

    assert_eq!(client.call(&Request::Shutdown).unwrap(), WireResponse::Draining);
    let report = handle.wait();
    assert!(report.drained);
    assert!(report.workers_clean);
    assert_eq!(report.shed, 3);
    // The pre-started workers got their fenced shutdown ops through the
    // replicas and exit cleanly.
    launcher.wait_exit(Duration::from_secs(10)).expect("workers drain cleanly");
}

/// Acceptance: a rank killed mid-request. The in-flight straggler —
/// submitted before the router could observe the death — is salvaged
/// onto the surviving replica (counted in `/stats.rerouted`), the
/// owning replica lame-ducks and, with `--heal off` (the default),
/// stays lame forever; the final drain is clean — the server process
/// never exits.
#[test]
fn killed_rank_mid_request_lame_ducks_and_drains_cleanly() {
    let cfg = small_cfg();
    let ds = Dataset::generate(&cfg).unwrap();
    let ccfg = ClusterServeConfig::local(program(), 2);
    let mut scfg = server_cfg(2);
    // A wide batching window so the kill lands while the request is
    // still in flight inside replica 0 (even on a heavily loaded CI
    // box, 40ms of sleep stays far inside 300ms).
    scfg.policy = BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(300) };
    let handle = start_cluster_server(scfg, &ds, &ccfg);
    let addr = handle.addr();
    assert!(handle.is_cluster());
    assert_eq!(handle.live_replicas(), 2);

    let mut client = Client::connect(addr).unwrap();
    for i in 0..2 {
        let (active, _) = infer_ok(&mut client, &Request::infer_row(i));
        assert_eq!(active, ds.truth_categories.contains(&i), "healthy row {i}");
    }

    // seq 2 -> replica 0. Kill rank 0 while the request sits in the
    // 300ms batching window; the eager health flag (flipped inside
    // kill_rank) catches the panel before any scatter, and the
    // straggler is diverted once to the surviving replica instead of
    // being failed — it was never scattered, so a re-run cannot
    // double-execute it.
    let t = std::thread::spawn(move || {
        let mut c = Client::connect(addr).unwrap();
        c.call(&Request::infer_row(0)).unwrap()
    });
    std::thread::sleep(Duration::from_millis(40));
    handle.kill_rank(0).expect("fault injection");
    match t.join().expect("in-flight client") {
        WireResponse::Infer { active, .. } => {
            assert_eq!(
                active,
                ds.truth_categories.contains(&0),
                "the salvaged straggler must answer correctly"
            );
        }
        other => panic!("expected the re-routed straggler to succeed, got {other:?}"),
    }

    // Replica 0 is lame; every subsequent request re-routes to replica
    // 1 and succeeds.
    assert_eq!(handle.live_replicas(), 1);
    for i in 0..4 {
        let (active, _) = infer_ok(&mut client, &Request::infer_row(i % cfg.batch));
        assert_eq!(active, ds.truth_categories.contains(&(i % cfg.batch)), "re-routed row");
    }

    let snap = stats(&mut client);
    let replicas = snap.req_arr("replicas").unwrap();
    let lame: Vec<bool> =
        replicas.iter().map(|r| r.req("lame").unwrap().as_bool().unwrap()).collect();
    assert_eq!(lame, vec![true, false]);
    let r0_ranks = replicas[0].req_arr("ranks").unwrap();
    assert!(!r0_ranks[0].req("alive").unwrap().as_bool().unwrap(), "rank 0 reported dead");
    let r1_ranks = replicas[1].req_arr("ranks").unwrap();
    assert!(r1_ranks[0].req("alive").unwrap().as_bool().unwrap(), "rank 1 alive");
    assert_eq!(snap.req_usize("live_replicas").unwrap(), 1);
    assert!(snap.req_usize("rerouted").unwrap() >= 1, "the straggler re-route must be counted");

    // `--heal off` (the default here) preserves the historical
    // contract: give a would-be healer ample time to act, then confirm
    // the replica is still lame and nothing was healed.
    std::thread::sleep(Duration::from_millis(300));
    let snap = stats(&mut client);
    let r0 = &snap.req_arr("replicas").unwrap()[0];
    assert!(r0.req("lame").unwrap().as_bool().unwrap(), "lame must persist with --heal off");
    let heal = r0.req("heal").unwrap();
    assert_eq!(heal.req_str("state").unwrap(), "off");
    assert_eq!(heal.req_usize("heals").unwrap(), 0);
    assert_eq!(snap.req_usize("live_replicas").unwrap(), 1);

    // Remote drain: replica 1 fences + shuts its rank down, the killed
    // rank is excluded from cleanliness, and everything was answered.
    assert_eq!(client.call(&Request::Shutdown).unwrap(), WireResponse::Draining);
    let report = handle.wait();
    assert!(report.drained, "drain must answer all in-flight work");
    assert!(report.workers_clean, "the surviving rank must exit cleanly");
}

/// Satellite: the black box under chaos. Kill a rank mid-fleet; the
/// flight recorder must hold the rank-death event strictly before the
/// lame-duck it caused (ordered by sequence number), and
/// `{"op":"health"}` must downgrade from `ok` to `degraded` naming the
/// lame replica and the dead rank.
#[test]
fn flight_recorder_and_health_capture_a_chaos_rank_kill() {
    let cfg = small_cfg();
    let ds = Dataset::generate(&cfg).unwrap();
    let ccfg = ClusterServeConfig::local(program(), 2);
    let handle = start_cluster_server(server_cfg(2), &ds, &ccfg);
    let mut client = Client::connect(handle.addr()).unwrap();

    let health = |client: &mut Client| match client.call(&Request::Health).expect("health call") {
        WireResponse::Health(h) => h,
        other => panic!("expected health response, got {other:?}"),
    };

    // Healthy fleet first: the verdict is ok with no reasons.
    for i in 0..2 {
        infer_ok(&mut client, &Request::infer_row(i));
    }
    let before = health(&mut client);
    assert_eq!(before.req_str("verdict").unwrap(), "ok");
    assert!(before.req_arr("reasons").unwrap().is_empty(), "{before}");
    assert_eq!(before.req_usize("ranks_alive").unwrap(), 2);

    // Kill rank 0, then drive a request into its replica (request
    // seq 2 -> replica 0) so the death is observed and recorded; the
    // straggler itself is salvaged onto the surviving replica.
    handle.kill_rank(0).expect("fault injection");
    match client.call(&Request::infer_row(0)).unwrap() {
        WireResponse::Infer { active, .. } => {
            assert_eq!(active, ds.truth_categories.contains(&0), "salvaged straggler");
        }
        other => panic!("expected the re-routed request to succeed, got {other:?}"),
    }

    // The verdict names the casualty.
    let after = health(&mut client);
    assert_eq!(after.req_str("verdict").unwrap(), "degraded", "{after}");
    let reasons: Vec<String> = after
        .req_arr("reasons")
        .unwrap()
        .iter()
        .map(|r| r.as_str().unwrap().to_string())
        .collect();
    assert!(reasons.iter().any(|r| r == "replica 0 is lame"), "{reasons:?}");
    assert!(reasons.iter().any(|r| r == "rank 0 is dead (replica 0)"), "{reasons:?}");
    assert_eq!(after.req_usize("live_replicas").unwrap(), 1);
    assert_eq!(after.req_usize("ranks_alive").unwrap(), 1);
    assert_eq!(after.req_usize("ranks_total").unwrap(), 2);

    // The flight recorder holds the forensic record, cause before
    // effect. (The ring is process-global and other tests in this
    // binary also down ranks, so scope every match to rank 0's detail
    // strings; each lame-duck is recorded after its rank-death, so the
    // first matching death must precede the first matching lame-duck.)
    let dump = match client.call(&Request::Flight).expect("flight call") {
        WireResponse::Flight(f) => f,
        other => panic!("expected flight response, got {other:?}"),
    };
    let local = flight::events_from_json(dump.req("local").unwrap()).expect("flight events");
    let death = local
        .iter()
        .find(|e| e.kind == flight::RANK_DEATH && e.detail.contains("rank 0"))
        .expect("a rank-death event for rank 0");
    let lame = local
        .iter()
        .find(|e| e.kind == flight::LAME_DUCK && e.detail.contains("rank 0"))
        .expect("a lame-duck event for rank 0");
    assert!(
        death.seq < lame.seq,
        "rank-death (seq {}) must precede lame-duck (seq {})",
        death.seq,
        lame.seq
    );
    // The dump also carries per-rank telemetry: the dead rank cannot
    // answer, the surviving one ships its events home.
    let ranks = dump.req_arr("ranks").unwrap();
    assert_eq!(ranks.len(), 2);
    assert!(!ranks[0].req("alive").unwrap().as_bool().unwrap(), "rank 0 is dead");
    assert!(ranks[1].req("alive").unwrap().as_bool().unwrap(), "rank 1 answers");

    let report = handle.shutdown();
    assert!(report.drained);
}

/// The chaos proxy's frame-surgery faults: a truncated or corrupted
/// scatter frame degrades the replica (detected at the protocol or
/// gather-cover layer — never silently) while the server keeps serving.
#[test]
fn truncated_and_corrupt_frames_degrade_the_replica_not_the_server() {
    let cfg = small_cfg();
    let ds = Dataset::generate(&cfg).unwrap();
    for kind in ["truncate", "corrupt"] {
        let launcher = Launcher::spawn(&LauncherConfig::local(program(), 2)).unwrap();
        let worker_addrs = launcher.addrs();
        let proxy = ChaosProxy::start(worker_addrs[0]);
        let ccfg = ClusterServeConfig {
            addrs: Some(vec![proxy.addr(), worker_addrs[1]]),
            ..ClusterServeConfig::local(program(), 2)
        };
        let handle = start_cluster_server(server_cfg(2), &ds, &ccfg);
        let mut client = Client::connect(handle.addr()).unwrap();
        for i in 0..2 {
            infer_ok(&mut client, &Request::infer_row(i));
        }

        let at = proxy.messages();
        proxy.set_fault(match kind {
            "truncate" => Fault::Truncate { index: at, keep: 12 },
            _ => Fault::Corrupt { index: at },
        });
        match client.call(&Request::infer_row(0)).unwrap() {
            WireResponse::Error { message } => {
                assert!(message.contains("failed"), "{kind}: unexpected error: {message}");
            }
            other => panic!("{kind}: expected an error, got {other:?}"),
        }
        for _ in 0..3 {
            infer_ok(&mut client, &Request::infer_row(1));
        }
        assert_eq!(handle.live_replicas(), 1, "{kind}: replica 0 must be lame");
        let report = handle.shutdown();
        assert!(report.drained, "{kind}");
        // rank 0's connection broke mid-fault so it cannot receive a
        // shutdown op; dropping the launcher reaps it. Cleanliness of a
        // full fenced drain is asserted by the other tests.
        drop(launcher);
    }
}

/// Weight-sharded serving under fault injection: a weights-mode replica
/// whose rank subset loses one rank's connection mid-pass — the chaos
/// proxy severs an exchange frame partway through the layer loop. The
/// panel gets a clean error (never a hang or a crash), the replica
/// lame-ducks, the server keeps serving on the surviving replica, and
/// the severed worker process itself survives to answer fresh
/// connections.
#[test]
fn severed_exchange_mid_layer_degrades_the_replica_not_the_server() {
    let cfg = small_cfg();
    let ds = Dataset::generate(&cfg).unwrap();
    // 4 ranks over 2 replicas: replica 0 holds a genuine 2-rank weight
    // shard (rows split 32/32) with rank 0 behind the proxy.
    let launcher = Launcher::spawn(&LauncherConfig::local(program(), 4)).unwrap();
    let worker_addrs = launcher.addrs();
    let proxy = ChaosProxy::start(worker_addrs[0]);
    let ccfg = ClusterServeConfig {
        options: ClusterOptions { partition: PartitionScheme::Weights, ..Default::default() },
        addrs: Some(vec![proxy.addr(), worker_addrs[1], worker_addrs[2], worker_addrs[3]]),
        ..ClusterServeConfig::local(program(), 4)
    };
    let handle = start_cluster_server(server_cfg(2), &ds, &ccfg);
    let mut client = Client::connect(handle.addr()).unwrap();

    // Healthy weights-mode pass through both replicas first.
    for i in 0..2 {
        let (active, _) = infer_ok(&mut client, &Request::infer_row(i));
        assert_eq!(active, ds.truth_categories.contains(&i), "healthy row {i}");
    }

    // Sever rank 0's request path on its next message: the replica's
    // layer loop dies partway through the per-layer exchanges.
    proxy.set_fault(Fault::Sever { after: proxy.messages() });
    match client.call(&Request::infer_row(0)).unwrap() {
        WireResponse::Error { message } => {
            assert!(message.contains("failed"), "unexpected error: {message}");
        }
        other => panic!("expected a clean error for the severed pass, got {other:?}"),
    }

    // The surviving replica keeps answering, bit-correct.
    for i in 0..4 {
        let (active, _) = infer_ok(&mut client, &Request::infer_row(i % cfg.batch));
        assert_eq!(active, ds.truth_categories.contains(&(i % cfg.batch)), "re-routed row");
    }
    assert_eq!(handle.live_replicas(), 1, "replica 0 must be lame");
    let snap = stats(&mut client);
    let lame: Vec<bool> = snap
        .req_arr("replicas")
        .unwrap()
        .iter()
        .map(|r| r.req("lame").unwrap().as_bool().unwrap())
        .collect();
    assert_eq!(lame, vec![true, false]);

    let report = handle.shutdown();
    assert!(report.drained, "every request answered despite the severed rank");

    // The severed worker process itself is still alive and serving: the
    // cut was a connection, not a rank.
    let mut direct = ClusterClient::connect(worker_addrs[0], WireFormat::Bin).unwrap();
    match direct.call(&ClusterRequest::Ping).unwrap() {
        ClusterReply::Pong { .. } => {}
        other => panic!("severed worker did not survive: {other:?}"),
    }
    match direct.call(&ClusterRequest::Shutdown).unwrap() {
        ClusterReply::Bye => {}
        other => panic!("unexpected shutdown reply: {other:?}"),
    }
    drop(launcher);
}

// ---------------------------------------------------------------------------
// Self-healing (tentpole): kill -> respawn -> re-adopt
// ---------------------------------------------------------------------------

/// Tentpole acceptance: kill a worker rank under a `--heal` fleet. The
/// healer must respawn the process, re-ship the weight recipe, and
/// swap the rebuilt coordinator back into rotation — after which every
/// row answers bit-identically to the pre-kill fleet, the health
/// verdict is back to `ok`, and the flight recorder holds the incident
/// in causal order (rank-death < lame-duck < replica-healed). The
/// server process never restarts.
#[test]
fn killed_rank_heals_and_serves_bit_identical_responses() {
    let cfg = small_cfg();
    let ds = Dataset::generate(&cfg).unwrap();
    let ccfg = ClusterServeConfig {
        heal: HealPolicy::parse("10x100").unwrap(),
        ..ClusterServeConfig::local(program(), 2)
    };
    let handle = start_cluster_server(server_cfg(2), &ds, &ccfg);
    let mut client = Client::connect(handle.addr()).unwrap();

    // Reference answers from the healthy fleet, both replicas.
    let before: Vec<(bool, Vec<f32>)> = (0..cfg.batch)
        .map(|i| {
            let (active, acts) = infer_ok(&mut client, &Request::infer_row(i));
            (active, acts.expect("activations"))
        })
        .collect();

    handle.kill_rank(0).expect("fault injection");
    // No traffic flows while we wait: detection (launcher health flag)
    // and the heal both belong to the healer thread alone.
    let t0 = std::time::Instant::now();
    loop {
        let snap = stats(&mut client);
        let r0 = &snap.req_arr("replicas").unwrap()[0];
        let lame = r0.req("lame").unwrap().as_bool().unwrap();
        let heal = r0.req("heal").unwrap();
        if !lame && heal.req_str("state").unwrap() == "healed" {
            assert!(heal.req_usize("heals").unwrap() >= 1, "{snap}");
            break;
        }
        assert!(t0.elapsed() < Duration::from_secs(30), "fleet did not heal: {snap}");
        std::thread::sleep(Duration::from_millis(20));
    }

    // The healed fleet answers every row with the exact same bits.
    for (i, (want_active, want_acts)) in before.iter().enumerate() {
        let (active, acts) = infer_ok(&mut client, &Request::infer_row(i));
        assert_eq!(active, *want_active, "row {i} after heal");
        let acts = acts.expect("activations after heal");
        assert_eq!(acts.len(), want_acts.len(), "row {i} after heal");
        for (j, (x, y)) in acts.iter().zip(want_acts).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "row {i} value {j} after heal: {x} != {y}");
        }
    }
    assert_eq!(handle.live_replicas(), 2, "the healed replica is back in rotation");

    // The verdict is back to ok with the full fleet alive.
    let health = match client.call(&Request::Health).unwrap() {
        WireResponse::Health(h) => h,
        other => panic!("expected health response, got {other:?}"),
    };
    assert_eq!(health.req_str("verdict").unwrap(), "ok", "{health}");
    assert_eq!(health.req_usize("ranks_alive").unwrap(), 2);

    // Causal order in the flight recorder. The ring is process-global
    // and shared with the other tests in this binary, but any lame-duck
    // follows its rank-death and any replica-healed follows its
    // lame-duck, so the first-of-each-kind ordering is invariant.
    let dump = match client.call(&Request::Flight).unwrap() {
        WireResponse::Flight(f) => f,
        other => panic!("expected flight response, got {other:?}"),
    };
    let local = flight::events_from_json(dump.req("local").unwrap()).expect("flight events");
    let death = local.iter().find(|e| e.kind == flight::RANK_DEATH).expect("rank-death");
    let lame = local.iter().find(|e| e.kind == flight::LAME_DUCK).expect("lame-duck");
    let healed =
        local.iter().find(|e| e.kind == flight::REPLICA_HEALED).expect("replica-healed");
    assert!(
        death.seq < lame.seq && lame.seq < healed.seq,
        "incident out of order: rank-death {} / lame-duck {} / replica-healed {}",
        death.seq,
        lame.seq,
        healed.seq
    );

    // Clean drain through the healed coordinator: the respawned worker
    // receives its fenced shutdown op like any other rank.
    assert_eq!(client.call(&Request::Shutdown).unwrap(), WireResponse::Draining);
    let report = handle.wait();
    assert!(report.drained, "drain must answer everything after a heal");
    assert!(report.workers_clean, "the respawned worker must exit cleanly");
}

/// Satellite: the background ping sweep. An adopted fleet (pre-started
/// addresses) has no launcher stdout flags, so a severed rank
/// connection is invisible until something touches the socket. With
/// `--ping-interval-ms`, the healer's sweep probes the idle
/// connections and lame-ducks the replica with no inference traffic
/// flowing at it.
#[test]
fn ping_sweep_lame_ducks_a_severed_adopted_rank_without_traffic() {
    let cfg = small_cfg();
    let ds = Dataset::generate(&cfg).unwrap();
    let mut launcher = Launcher::spawn(&LauncherConfig::local(program(), 2)).unwrap();
    let worker_addrs = launcher.addrs();
    let proxy = ChaosProxy::start(worker_addrs[0]);
    let ccfg = ClusterServeConfig {
        addrs: Some(vec![proxy.addr(), worker_addrs[1]]),
        ping_interval: Some(Duration::from_millis(25)),
        ..ClusterServeConfig::local(program(), 2)
    };
    let handle = start_cluster_server(server_cfg(2), &ds, &ccfg);
    let mut client = Client::connect(handle.addr()).unwrap();
    for i in 0..2 {
        infer_ok(&mut client, &Request::infer_row(i));
    }

    // Sever replica 0's rank connection on its next message — which is
    // the sweep's own ping, not client traffic.
    proxy.set_fault(Fault::Sever { after: proxy.messages() });
    let t0 = std::time::Instant::now();
    loop {
        let snap = stats(&mut client);
        let r0 = &snap.req_arr("replicas").unwrap()[0];
        let lame = r0.req("lame").unwrap().as_bool().unwrap();
        let alive = r0.req_arr("ranks").unwrap()[0].req("alive").unwrap().as_bool().unwrap();
        if lame && !alive {
            // Sweep-only detection: no healing was configured.
            assert_eq!(r0.req("heal").unwrap().req_str("state").unwrap(), "off");
            break;
        }
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "the ping sweep never observed the severed rank: {snap}"
        );
        std::thread::sleep(Duration::from_millis(20));
    }

    // The surviving replica keeps serving, bit-correct.
    for i in 0..4 {
        let (active, _) = infer_ok(&mut client, &Request::infer_row(i % cfg.batch));
        assert_eq!(active, ds.truth_categories.contains(&(i % cfg.batch)), "post-sweep row");
    }
    let report = handle.shutdown();
    assert!(report.drained);
    // The severed worker never saw its shutdown op (its connection is
    // gone); reap it directly like any adopted-fleet supervisor would.
    drop(proxy);
    launcher.kill_rank(0).ok();
    launcher.wait_exit(Duration::from_secs(10)).ok();
}

// ---------------------------------------------------------------------------
// Wire-negotiation downgrade (satellite): v1-era json-only peers
// ---------------------------------------------------------------------------

fn result_reply(start: usize, count: usize) -> ClusterReply {
    ClusterReply::Result(Box::new(ShardResult {
        rank: 0,
        start,
        count,
        categories: vec![],
        activations: vec![],
        live_per_layer: vec![],
        layer_secs: vec![],
        edges_traversed: 0,
        secs: 0.0,
        trace: TraceId::NONE,
        spans: vec![],
    }))
}

/// A protocol-v1-era peer: understands both framings on the read side
/// (so a stray binary frame is *observed*, not hung on), but answers
/// `hello` with `version:1, wire:json` and only ever speaks JSON.
/// Every message it reads is reported back to the test together with
/// the wire it arrived in.
fn v1_json_peer(
    listener: TcpListener,
    neurons: usize,
    tx: mpsc::Sender<(String, WireFormat, Option<Vec<f32>>)>,
) {
    use std::io::{BufReader, Write};
    let Ok((stream, _)) = listener.accept() else { return };
    stream.set_nodelay(true).ok();
    let Ok(mut writer) = stream.try_clone() else { return };
    let mut reader = BufReader::new(stream);
    // (start, rows, chunks left) of an open chunked scatter.
    let mut pending: Option<(usize, usize, usize)> = None;
    loop {
        let (req, wire) = match read_request(&mut reader, CONTROL_FRAME_CAP) {
            Ok(ReadOutcome::Msg(req, wire)) => (req, wire),
            Ok(ReadOutcome::Invalid(e, wire)) => {
                let _ = tx.send((format!("invalid: {e:#}"), wire, None));
                return;
            }
            Ok(ReadOutcome::Eof) | Err(_) => return,
        };
        let payload = match &req {
            ClusterRequest::Shard { features, .. }
            | ClusterRequest::ShardChunk { features, .. } => Some(features.clone()),
            _ => None,
        };
        let _ = tx.send((req.op().to_string(), wire, payload));
        let reply = match req {
            ClusterRequest::Hello { .. } => {
                Some(ClusterReply::Hello { version: 1, wire: WireFormat::Json })
            }
            ClusterRequest::Ping => Some(ClusterReply::Pong { version: 1 }),
            ClusterRequest::Load { model, .. } => Some(ClusterReply::Loaded {
                rank: 0,
                neurons: model.neurons,
                layers: model.layers,
            }),
            ClusterRequest::Shard { start, features, .. } => {
                Some(result_reply(start, features.len() / neurons.max(1)))
            }
            ClusterRequest::ShardBegin { start, rows, chunks, .. } => {
                if chunks == 0 {
                    Some(result_reply(start, rows))
                } else {
                    pending = Some((start, rows, chunks));
                    None
                }
            }
            ClusterRequest::ShardChunk { .. } => {
                let done = match &mut pending {
                    Some((_, _, left)) => {
                        *left -= 1;
                        Some(*left == 0)
                    }
                    None => None,
                };
                match done {
                    None => Some(ClusterReply::Error { message: "no open shard stream".into() }),
                    Some(false) => None,
                    Some(true) => {
                        let (start, rows, _) = pending.take().expect("open stream");
                        Some(result_reply(start, rows))
                    }
                }
            }
            ClusterRequest::Exchange { .. } => {
                // v4-only verb; a v1 peer would never see it (the
                // coordinator refuses weights mode at connect).
                Some(ClusterReply::Error { message: "unknown op".into() })
            }
            ClusterRequest::Shutdown => {
                let _ = write_reply(&mut writer, &ClusterReply::Bye, WireFormat::Json);
                let _ = writer.flush();
                return;
            }
        };
        if let Some(reply) = reply {
            if write_reply(&mut writer, &reply, WireFormat::Json).is_err() {
                return;
            }
            let _ = writer.flush();
        }
    }
}

/// Satellite property test: a bin-default coordinator connecting to a
/// v1-only (json) peer — through the chaos proxy with randomized
/// arrival jitter — must settle on json, and every subsequent message
/// (ping, whole or chunked scatters with random payloads) must arrive
/// on the json wire with its f32 payload bit-intact: no frames lost,
/// no frames mis-encoded.
#[test]
fn v1_json_only_peer_downgrades_bin_coordinator_losslessly() {
    let neurons = 8;
    Runner::new(12, 0xD0C5).run("wire-downgrade", |rng| {
        let listener = TcpListener::bind("127.0.0.1:0").expect("peer listener");
        let peer_addr = listener.local_addr().expect("peer addr");
        let (tx, rx) = mpsc::channel();
        let peer = std::thread::spawn(move || v1_json_peer(listener, neurons, tx));
        // Randomized hello/frame arrival: every message is held for a
        // random few milliseconds by the proxy.
        let jitter = Duration::from_millis(proptest::usize_in(rng, 0, 15) as u64);
        let proxy = ChaosProxy::start_with(peer_addr, Fault::Delay { after: 0, delay: jitter });

        let mut client = match ClusterClient::connect(proxy.addr(), WireFormat::Bin) {
            Ok(c) => c,
            Err(e) => return Err(format!("handshake failed: {e:#}")),
        };
        if client.wire() != WireFormat::Json {
            return Err(format!("settled on {}, expected json", client.wire()));
        }
        if let Err(e) = client.ping() {
            return Err(format!("ping after downgrade: {e:#}"));
        }

        let rows = proptest::usize_in(rng, 1, 5);
        let feats = proptest::vec_f32(rng, rows * neurons, -8.0, 8.0);
        let chunk_rows = *proptest::choose(rng, &[None, Some(2)]);
        let reply = match client.send_shard(3, &feats, neurons, chunk_rows, TraceId::NONE) {
            Ok(r) => r,
            Err(e) => return Err(format!("scatter after downgrade: {e:#}")),
        };
        match reply {
            ClusterReply::Result(r) => {
                if r.start != 3 || r.count != rows {
                    let (s, c) = (r.start, r.count);
                    return Err(format!("peer echoed [{s}, +{c}), sent [3, +{rows})"));
                }
            }
            other => return Err(format!("unexpected scatter reply {other:?}")),
        }
        match client.call(&ClusterRequest::Shutdown) {
            Ok(ClusterReply::Bye) => {}
            Ok(other) => return Err(format!("unexpected shutdown reply {other:?}")),
            Err(e) => return Err(format!("shutdown: {e:#}")),
        }
        peer.join().map_err(|_| "peer thread panicked".to_string())?;

        // Everything the peer observed must be json-framed, and the
        // scatter payload must re-assemble bit-exactly.
        let msgs: Vec<(String, WireFormat, Option<Vec<f32>>)> = rx.try_iter().collect();
        if msgs.is_empty() {
            return Err("peer observed no messages".into());
        }
        let mut received: Vec<f32> = Vec::new();
        for (op, wire, payload) in &msgs {
            if *wire != WireFormat::Json {
                return Err(format!("{op} arrived as {wire} after a json downgrade"));
            }
            if op.starts_with("invalid") {
                return Err(format!("peer could not parse a message: {op}"));
            }
            if let Some(p) = payload {
                received.extend_from_slice(p);
            }
        }
        if received.len() != feats.len()
            || received.iter().zip(&feats).any(|(a, b)| a.to_bits() != b.to_bits())
        {
            return Err("scatter payload lost or altered across the downgrade".into());
        }
        Ok(())
    });
}
