//! Cross-language determinism: the Rust generators (PRNG, RadiX-Net
//! topology, synthetic MNIST, the network oracle) must reproduce the
//! Python implementations bit-for-bit / within float tolerance.
//!
//! The golden file is exported by python/tests/test_golden_export.py
//! (`make test` runs pytest first); without it these tests skip.

use spdnn::data::mnist_synth;
use spdnn::engine::EllEngine;
use spdnn::radixnet::{topology, RadixNet, Topology};
use spdnn::util::json::Json;
use spdnn::util::prng::Xoshiro256;

fn golden() -> Option<Json> {
    let path = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/golden_cross.json");
    match std::fs::read_to_string(&path) {
        Ok(text) => Some(Json::parse(&text).expect("golden file parses")),
        Err(_) => {
            eprintln!("SKIP: {} missing — run pytest first (make test)", path.display());
            None
        }
    }
}

#[test]
fn prng_streams_match_python() {
    let Some(g) = golden() else { return };
    let want: Vec<u64> = g
        .req_arr("xoshiro_seed42_u64")
        .unwrap()
        .iter()
        .map(|v| v.as_str().unwrap().parse::<u64>().unwrap())
        .collect();
    let mut rng = Xoshiro256::new(42);
    let got: Vec<u64> = (0..want.len()).map(|_| rng.next_u64()).collect();
    assert_eq!(got, want);

    let want_b: Vec<u64> = g
        .req_arr("xoshiro_seed7_below10")
        .unwrap()
        .iter()
        .map(|v| v.as_i64().unwrap() as u64)
        .collect();
    let mut rng = Xoshiro256::new(7);
    let got_b: Vec<u64> = (0..want_b.len()).map(|_| rng.next_below(10)).collect();
    assert_eq!(got_b, want_b);

    let want_f: Vec<f64> =
        g.req_arr("xoshiro_seed42_f32").unwrap().iter().map(|v| v.as_f64().unwrap()).collect();
    let mut rng = Xoshiro256::new(42);
    for w in want_f {
        assert!((rng.next_f32() as f64 - w).abs() < 1e-7);
    }
}

#[test]
fn butterfly_topology_matches_python() {
    let Some(g) = golden() else { return };
    for (key, layer) in [("butterfly_n64_k4_l0_rows", 0usize), ("butterfly_n64_k4_l1_rows", 1)] {
        let want: Vec<Vec<u32>> = g
            .req_arr(key)
            .unwrap()
            .iter()
            .map(|row| row.as_arr().unwrap().iter().map(|c| c.as_i64().unwrap() as u32).collect())
            .collect();
        let got = topology::butterfly_layer(64, 4, layer);
        assert_eq!(&got[..want.len()], want.as_slice(), "{key}");
    }
    let want_strides: Vec<usize> = g
        .req_arr("butterfly_n1024_k32_strides")
        .unwrap()
        .iter()
        .map(|v| v.as_usize().unwrap())
        .collect();
    assert_eq!(topology::butterfly_strides(1024, 32), want_strides);
}

#[test]
fn random_topology_matches_python() {
    let Some(g) = golden() else { return };
    let want: Vec<Vec<u32>> = g
        .req_arr("random_n64_k4_l1_s5_rows")
        .unwrap()
        .iter()
        .map(|row| row.as_arr().unwrap().iter().map(|c| c.as_i64().unwrap() as u32).collect())
        .collect();
    let got = topology::random_layer(64, 4, 1, 5);
    assert_eq!(&got[..want.len()], want.as_slice());
}

#[test]
fn mnist_images_match_python() {
    let Some(g) = golden() else { return };
    let want: Vec<Vec<u8>> = g
        .req_arr("mnist_n256_c4_s2")
        .unwrap()
        .iter()
        .map(|img| img.as_arr().unwrap().iter().map(|p| p.as_i64().unwrap() as u8).collect())
        .collect();
    let got = mnist_synth::generate(256, 4, 2).unwrap();
    assert_eq!(got, want);
}

#[test]
fn network_run_matches_python_oracle() {
    let Some(g) = golden() else { return };
    let neurons = 64;
    let layers = 6;
    let k = 4;
    let batch = 12;
    let net = RadixNet::new(neurons, layers, k, Topology::Butterfly, 0x5BD1).unwrap();
    let bias = vec![-0.3f32; neurons];
    let mut y = mnist_synth::generate_features(neurons, batch, 11).unwrap();
    let engine = EllEngine::new(1);
    let mut scratch = vec![0f32; y.len()];
    for l in 0..layers {
        let w = net.layer_ell(l);
        engine.layer(&w, &bias, &y, &mut scratch);
        std::mem::swap(&mut y, &mut scratch);
    }

    let want_sum = g.req_f64("net_n64_l6_final_sum").unwrap();
    let got_sum: f64 = y.iter().map(|&v| v as f64).sum();
    assert!((got_sum - want_sum).abs() < 1e-2 * want_sum.abs().max(1.0), "{got_sum} vs {want_sum}");

    let want_cats: Vec<usize> = g
        .req_arr("net_n64_l6_categories")
        .unwrap()
        .iter()
        .map(|v| v.as_usize().unwrap())
        .collect();
    let got_cats: Vec<usize> = (0..batch)
        .filter(|&i| y[i * neurons..(i + 1) * neurons].iter().any(|&v| v > 0.0))
        .collect();
    assert_eq!(got_cats, want_cats);

    let want_row: Vec<f64> =
        g.req_arr("net_n64_l6_row0").unwrap().iter().map(|v| v.as_f64().unwrap()).collect();
    for (a, b) in y[..neurons].iter().zip(&want_row) {
        assert!((*a as f64 - b).abs() < 1e-4);
    }
}
