//! Integration: serving mode over the PJRT backend + failure injection.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use spdnn::coordinator::batcher::{BatchPolicy, InferenceServer, ServeBackend, ServedModel};
use spdnn::data::Dataset;
use spdnn::util::config::RuntimeConfig;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts — run `make artifacts`");
        None
    }
}

fn model() -> (ServedModel, Dataset) {
    let cfg = RuntimeConfig { neurons: 64, layers: 4, k: 4, batch: 12, ..Default::default() };
    let ds = Dataset::generate(&cfg).unwrap();
    (
        ServedModel {
            layers: Arc::new(ds.layers.clone()),
            bias: ds.bias.clone(),
            neurons: 64,
            k: 4,
        },
        ds,
    )
}

#[test]
fn pjrt_server_matches_offline_truth() {
    let Some(dir) = artifacts_dir() else { return };
    let (m, ds) = model();
    let server = InferenceServer::start(
        m,
        ServeBackend::Pjrt { artifacts: dir },
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(5) },
    );
    for i in 0..ds.cfg.batch {
        let feats = ds.features[i * 64..(i + 1) * 64].to_vec();
        let resp = server.classify(feats).unwrap();
        assert_eq!(resp.active, ds.truth_categories.contains(&i), "feature {i}");
    }
    server.shutdown();
}

#[test]
fn pjrt_server_backend_failure_is_reported_not_hung() {
    // Nonexistent artifacts directory: every request must get an error
    // (not a hang, not a panic).
    let (m, ds) = model();
    let server = InferenceServer::start(
        m,
        ServeBackend::Pjrt { artifacts: PathBuf::from("/nonexistent/artifacts") },
        BatchPolicy::default(),
    );
    let err = server.classify(ds.features[..64].to_vec());
    assert!(err.is_err());
    let msg = format!("{:#}", err.unwrap_err());
    assert!(msg.contains("backend init failed"), "{msg}");
    server.shutdown();
}

#[test]
fn server_survives_many_concurrent_clients() {
    let (m, ds) = model();
    let server = Arc::new(InferenceServer::start(
        m,
        ServeBackend::native(1, 12),
        BatchPolicy { max_batch: 16, max_wait: Duration::from_millis(1) },
    ));
    let ds = Arc::new(ds);
    std::thread::scope(|scope| {
        for t in 0..4 {
            let server = server.clone();
            let ds = ds.clone();
            scope.spawn(move || {
                for i in 0..20 {
                    let f = (t * 7 + i) % ds.cfg.batch;
                    let feats = ds.features[f * 64..(f + 1) * 64].to_vec();
                    let resp = server.classify(feats).unwrap();
                    assert_eq!(resp.active, ds.truth_categories.contains(&f));
                }
            });
        }
    });
}
