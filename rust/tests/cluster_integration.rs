//! End-to-end cluster tests: real worker-rank OS processes (the built
//! `spdnn` binary via CARGO_BIN_EXE) behind the rank-0 coordinator.
//!
//! Covers the acceptance bar of the cluster subsystem: bit-identity
//! with single-process inference through the baseline CSR engine — on
//! both wire formats, under the pipelined chunked scatter, and in
//! weight-sharded mode (`--partition weights`, at rank counts that do
//! and do not divide the row count) — exact cover of the scattered
//! feature ranges, the oversized-line frame cap, and clean drain when a
//! worker process is killed mid-flight.

use std::path::PathBuf;

use spdnn::cluster::{
    ClusterClient, ClusterOptions, ClusterReply, ClusterRequest, Launcher, LauncherConfig,
    LocalCluster, ModelSpec, PartitionScheme, WireFormat, CONTROL_FRAME_CAP,
};
use spdnn::coordinator::NativeSpec;
use spdnn::data::Dataset;
use spdnn::engine::{CsrEngine, EngineKind};
use spdnn::formats::convert::ell_to_csr;
use spdnn::util::config::RuntimeConfig;

fn program() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_spdnn"))
}

fn small_cfg() -> RuntimeConfig {
    RuntimeConfig { neurons: 64, layers: 6, k: 4, batch: 24, ..Default::default() }
}

fn spec(engine: EngineKind) -> NativeSpec {
    NativeSpec { engine, minibatch: 12, slice: 16, threads: 1 }
}

/// Single-process reference through the baseline CSR engine: surviving
/// categories plus their compacted final activations.
fn csr_reference(ds: &Dataset) -> (Vec<usize>, Vec<f32>) {
    let n = ds.cfg.neurons;
    let mut y = ds.features.clone();
    let mut scratch = vec![0f32; y.len()];
    for w in &ds.layers {
        let csr = ell_to_csr(w).unwrap();
        CsrEngine.layer(&csr, &ds.bias, &y, &mut scratch);
        std::mem::swap(&mut y, &mut scratch);
    }
    let mut categories = Vec::new();
    let mut activations = Vec::new();
    for i in 0..ds.cfg.batch {
        let row = &y[i * n..(i + 1) * n];
        if row.iter().any(|&v| v > 0.0) {
            categories.push(i);
            activations.extend_from_slice(row);
        }
    }
    (categories, activations)
}

#[test]
fn two_rank_cluster_is_bit_identical_to_single_process_csr() {
    let cfg = small_cfg();
    let ds = Dataset::generate(&cfg).unwrap();
    let (want_cats, want_acts) = csr_reference(&ds);
    assert_eq!(want_cats, ds.truth_categories, "reference sanity");

    let model = ModelSpec::from_config(&cfg);
    let mut cluster =
        LocalCluster::start(&program(), 2, &model, spec(EngineKind::Ell), cfg.prune).unwrap();
    assert_eq!(cluster.ranks(), 2);
    let report = cluster.run(&ds.features).unwrap();
    cluster.stop().expect("clean shutdown");

    assert_eq!(report.categories, want_cats);
    assert_eq!(report.activations.len(), want_acts.len());
    for (i, (a, b)) in report.activations.iter().zip(&want_acts).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "activation {i}: {a} != {b}");
    }
    assert_eq!(report.per_layer_imbalance.len(), cfg.layers);
    assert!(report.edges_per_sec > 0.0);
}

#[test]
fn scatter_exactly_covers_the_feature_panel() {
    let cfg = RuntimeConfig { neurons: 64, layers: 4, k: 4, batch: 23, ..Default::default() };
    let ds = Dataset::generate(&cfg).unwrap();
    let model = ModelSpec::from_config(&cfg);
    let mut cluster =
        LocalCluster::start(&program(), 3, &model, spec(EngineKind::Sliced), cfg.prune).unwrap();
    let report = cluster.run(&ds.features).unwrap();
    cluster.stop().expect("clean shutdown");

    // Exact cover: contiguous, disjoint, ordered, summing to the batch.
    assert_eq!(report.parts.len(), 3);
    let mut pos = 0usize;
    for (rank, (p, s)) in report.parts.iter().zip(&report.shards).enumerate() {
        assert_eq!(p.worker, rank);
        assert_eq!(p.start, pos, "partition {rank} not contiguous");
        assert_eq!(s.start, p.start, "shard {rank} echoes its range");
        assert_eq!(s.count, p.count);
        // Every category a rank reports lives inside its own range.
        assert!(s.categories.iter().all(|&c| c >= p.start && c < p.start + p.count));
        pos += p.count;
    }
    assert_eq!(pos, cfg.batch, "partitions must cover the whole panel");
    // 23 over 3 ranks: 8 + 8 + 7.
    let counts: Vec<usize> = report.parts.iter().map(|p| p.count).collect();
    assert_eq!(counts, vec![8, 8, 7]);
    assert_eq!(report.categories, ds.truth_categories);
}

#[test]
fn more_ranks_than_features_still_matches() {
    let cfg = RuntimeConfig { neurons: 64, layers: 3, k: 4, batch: 2, ..Default::default() };
    let ds = Dataset::generate(&cfg).unwrap();
    let model = ModelSpec::from_config(&cfg);
    // Rank 2 receives an empty shard and must still answer correctly.
    let mut cluster =
        LocalCluster::start(&program(), 3, &model, spec(EngineKind::Ell), cfg.prune).unwrap();
    let report = cluster.run(&ds.features).unwrap();
    cluster.stop().expect("clean shutdown");
    assert_eq!(report.categories, ds.truth_categories);
    assert_eq!(report.parts[2].count, 0);
    assert!(report.shards[2].categories.is_empty());
}

#[test]
fn killed_worker_propagates_and_the_rest_drain_cleanly() {
    let cfg = small_cfg();
    let ds = Dataset::generate(&cfg).unwrap();
    let model = ModelSpec::from_config(&cfg);
    let mut cluster =
        LocalCluster::start(&program(), 2, &model, spec(EngineKind::Ell), cfg.prune).unwrap();
    // A healthy pass first, so the failure below is attributable.
    let report = cluster.run(&ds.features).unwrap();
    assert_eq!(report.categories, ds.truth_categories);

    cluster.kill_rank(0).unwrap();
    let err = cluster.run(&ds.features).unwrap_err().to_string();
    assert!(
        err.contains("rank 0") || err.contains("connection"),
        "error should surface the dead rank, got: {err}"
    );
    // The surviving rank still drains cleanly on shutdown.
    cluster.stop().expect("surviving ranks must drain cleanly");
}

/// Tentpole acceptance: binary transport — whole-shard and pipelined
/// chunked — is bit-identical to the JSON wire (which is itself pinned
/// to the CSR reference above), and cuts scatter bytes by >=3x.
#[test]
fn binary_and_chunked_scatter_match_json_bit_exactly() {
    let cfg = small_cfg();
    let ds = Dataset::generate(&cfg).unwrap();
    let model = ModelSpec::from_config(&cfg);
    let run = |opts: ClusterOptions| {
        let mut cluster =
            LocalCluster::start_with(&program(), 2, &model, spec(EngineKind::Ell), cfg.prune, opts)
                .unwrap();
        let report = cluster.run(&ds.features).unwrap();
        cluster.stop().expect("clean shutdown");
        report
    };
    let json = run(ClusterOptions { wire: WireFormat::Json, ..Default::default() });
    let bin = run(ClusterOptions { wire: WireFormat::Bin, ..Default::default() });
    let chunked =
        run(ClusterOptions { wire: WireFormat::Bin, chunk_rows: Some(5), ..Default::default() });

    assert_eq!(json.categories, ds.truth_categories);
    for (name, r) in [("bin", &bin), ("bin+chunk", &chunked)] {
        assert_eq!(r.categories, json.categories, "{name}: categories");
        assert_eq!(r.activations.len(), json.activations.len(), "{name}: activation count");
        for (i, (a, b)) in r.activations.iter().zip(&json.activations).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "{name}: activation {i}: {a} != {b}");
        }
        // Same compute, different transport: per-layer live trajectories
        // (and thus the imbalance report) must agree exactly.
        for (s_r, s_j) in r.shards.iter().zip(&json.shards) {
            assert_eq!(s_r.live_per_layer, s_j.live_per_layer, "{name}: live trajectory");
        }
    }
    // The headline claim of the binary wire (ISSUE 4 acceptance bar).
    assert!(
        json.scatter_bytes >= 3 * bin.scatter_bytes,
        "binary scatter must be >=3x smaller: json {} B vs bin {} B",
        json.scatter_bytes,
        bin.scatter_bytes
    );
    // Chunking adds framing overhead but never panel bytes: stay well
    // under the JSON volume.
    assert!(chunked.scatter_bytes < json.scatter_bytes);
}

/// Tentpole acceptance: weight-sharded execution (`--partition
/// weights`) is bit-identical to single-process inference through the
/// sliced engine, at a rank count that divides the row count evenly (2)
/// and one that does not (3 over 64 rows: 22 + 21 + 21). The report
/// must carry the per-layer exchange volume.
#[test]
fn weight_sharded_passes_match_the_sliced_engine_bit_exactly() {
    let cfg = small_cfg();
    let ds = Dataset::generate(&cfg).unwrap();
    let (want_cats, want_acts) = csr_reference(&ds);
    assert_eq!(want_cats, ds.truth_categories, "reference sanity");

    let model = ModelSpec::from_config(&cfg);
    for ranks in [2usize, 3] {
        let opts = ClusterOptions { partition: PartitionScheme::Weights, ..Default::default() };
        let mut cluster = LocalCluster::start_with(
            &program(),
            ranks,
            &model,
            spec(EngineKind::Sliced),
            cfg.prune,
            opts,
        )
        .unwrap();
        let report = cluster.run(&ds.features).unwrap();
        cluster.stop().expect("clean shutdown");

        assert_eq!(report.partition, PartitionScheme::Weights, "ranks={ranks}");
        assert_eq!(report.categories, want_cats, "ranks={ranks}: categories");
        assert_eq!(report.activations.len(), want_acts.len(), "ranks={ranks}");
        for (i, (a, b)) in report.activations.iter().zip(&want_acts).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "ranks={ranks}: activation {i}: {a} != {b}");
        }
        // The parts cover the weight rows, not the feature panel.
        let rows: usize = report.parts.iter().map(|p| p.count).sum();
        assert_eq!(rows, cfg.neurons, "ranks={ranks}: weight rows exactly covered");
        // Per-layer communication volume: one entry per layer, every
        // pre-extinction layer non-zero (live features always remain on
        // this instance), totals matching the pass-level counters.
        let xb = &report.per_layer_exchange_bytes;
        assert_eq!(xb.len(), cfg.layers, "ranks={ranks}");
        assert!(xb.iter().all(|&b| b > 0), "ranks={ranks}: every layer exchanged bytes");
        assert_eq!(
            xb.iter().sum::<u64>(),
            report.scatter_bytes + report.gather_bytes,
            "ranks={ranks}: exchange series must sum to the wire totals"
        );
    }
}

/// Satellite regression: a peer streaming one giant line (no newline
/// until past the cap) gets a protocol error and a dropped connection —
/// the worker process itself survives and keeps serving.
#[test]
fn oversized_line_gets_a_protocol_error_not_a_dead_worker() {
    use std::io::{BufRead, BufReader, Write};

    let mut launcher = Launcher::spawn(&LauncherConfig::local(program(), 1)).unwrap();
    let addr = launcher.addrs()[0];

    let mut stream = std::net::TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    // No model is loaded on this connection, so the control cap is in
    // force; exceed it without ever sending a newline. The writes may
    // legitimately fail part-way once the worker drops the connection.
    let junk = vec![b'x'; CONTROL_FRAME_CAP + (1 << 16)];
    let _ = stream.write_all(&junk);
    let _ = stream.flush();
    let mut line = String::new();
    let _ = reader.read_line(&mut line);
    if !line.is_empty() {
        assert!(
            line.contains("exceeds") && line.contains("error"),
            "expected a frame-cap protocol error, got: {line}"
        );
    }
    drop(reader);
    drop(stream);

    // The rank must still be alive and serving fresh connections.
    let mut client = ClusterClient::connect(addr, WireFormat::Bin).unwrap();
    match client.call(&ClusterRequest::Ping).unwrap() {
        ClusterReply::Pong { .. } => {}
        other => panic!("worker did not survive the hostile line: {other:?}"),
    }
    match client.call(&ClusterRequest::Shutdown).unwrap() {
        ClusterReply::Bye => {}
        other => panic!("unexpected shutdown reply: {other:?}"),
    }
    launcher.wait_exit(std::time::Duration::from_secs(10)).unwrap();
}

#[test]
fn repeated_passes_reuse_the_loaded_replica() {
    let cfg = small_cfg();
    let ds = Dataset::generate(&cfg).unwrap();
    let model = ModelSpec::from_config(&cfg);
    let mut cluster =
        LocalCluster::start(&program(), 2, &model, spec(EngineKind::Sliced), cfg.prune).unwrap();
    for pass in 0..3 {
        let report = cluster.run(&ds.features).unwrap();
        assert_eq!(report.categories, ds.truth_categories, "pass {pass}");
    }
    cluster.stop().expect("clean shutdown");
}
