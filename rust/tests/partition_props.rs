//! Property tests for `coordinator::partition` — the static sharding
//! substrate used by the offline coordinator (features -> workers), the
//! serving router (request slots -> replicas) and the weight-sharded
//! cluster mode (weight rows -> ranks, stitched back per layer).

use spdnn::coordinator::partition::{imbalance, partition_even};
use spdnn::formats::ell::EllMatrix;
use spdnn::util::proptest::{self, Runner};

#[test]
fn covers_each_index_exactly_once() {
    Runner::new(128, 0x5EED).run("partition-cover-exactly-once", |rng| {
        let workers = proptest::usize_in(rng, 1, 40);
        // Half the cases force the batch < workers regime.
        let batch = if rng.next_f32() < 0.5 {
            proptest::usize_in(rng, 0, workers.saturating_sub(1))
        } else {
            proptest::usize_in(rng, 0, 400)
        };
        let parts = partition_even(batch, workers);
        if parts.len() != workers {
            return Err(format!("expected {workers} partitions, got {}", parts.len()));
        }
        let mut seen = vec![0usize; batch];
        for p in &parts {
            for i in p.start..p.start + p.count {
                if i >= batch {
                    return Err(format!("index {i} outside 0..{batch}"));
                }
                seen[i] += 1;
            }
        }
        if let Some(i) = seen.iter().position(|&c| c != 1) {
            return Err(format!("index {i} covered {} times", seen[i]));
        }
        Ok(())
    });
}

/// Weights-mode sharding property (tentpole): partitioning a layer's
/// weight rows with `partition_even` and slicing with `row_slice` must
/// cover every row of every layer exactly once — index and value panels
/// bit-identical to the original, no overlap, no gap — including rank
/// counts that do NOT divide the neuron count.
#[test]
fn weight_row_shards_cover_every_layer_exactly_once() {
    Runner::new(64, 0x0EE1).run("weight-shard-cover", |rng| {
        let neurons = proptest::usize_in(rng, 1, 96);
        let k = proptest::usize_in(rng, 1, neurons.min(4));
        let ranks = proptest::usize_in(rng, 1, 7);
        // A small multi-layer "model" with randomized sparsity patterns.
        let layers: Vec<EllMatrix> = (0..3)
            .map(|_| {
                let rows: Vec<Vec<(u32, f32)>> = (0..neurons)
                    .map(|_| {
                        (0..k)
                            .map(|_| {
                                let c = proptest::usize_in(rng, 0, neurons - 1) as u32;
                                (c, rng.next_f32() - 0.5)
                            })
                            .collect()
                    })
                    .collect();
                EllMatrix::from_rows(neurons, neurons, k, &rows).expect("ell build")
            })
            .collect();

        let parts = partition_even(neurons, ranks);
        for (l, w) in layers.iter().enumerate() {
            let mut pos = 0usize;
            let mut index = Vec::with_capacity(w.index.len());
            let mut value = Vec::with_capacity(w.value.len());
            for p in &parts {
                if p.start != pos {
                    return Err(format!(
                        "layer {l}: rank {} starts at {} (gap/overlap at {pos})",
                        p.worker, p.start
                    ));
                }
                let s = w.row_slice(p.start, p.count);
                if s.nrows != p.count || s.ncols != neurons || s.k != k {
                    return Err(format!("layer {l}: rank {} slice shape wrong", p.worker));
                }
                index.extend_from_slice(&s.index);
                value.extend_from_slice(&s.value);
                pos += p.count;
            }
            if pos != neurons {
                return Err(format!("layer {l}: shards cover {pos} of {neurons} rows"));
            }
            // Exact cover: re-concatenating the slices reproduces the
            // layer's packed panels bit-for-bit.
            if index != w.index {
                return Err(format!("layer {l}: stitched index panel differs"));
            }
            if value.len() != w.value.len()
                || value.iter().zip(&w.value).any(|(a, b)| a.to_bits() != b.to_bits())
            {
                return Err(format!("layer {l}: stitched value panel differs"));
            }
        }
        Ok(())
    });
}

#[test]
fn partitions_differ_by_at_most_one() {
    Runner::new(128, 0xBA1A).run("partition-even-sizes", |rng| {
        let workers = proptest::usize_in(rng, 1, 40);
        let batch = proptest::usize_in(rng, 0, 400);
        let counts: Vec<usize> =
            partition_even(batch, workers).iter().map(|p| p.count).collect();
        let (mn, mx) = (*counts.iter().min().unwrap(), *counts.iter().max().unwrap());
        if mx - mn > 1 {
            return Err(format!("uneven split: min {mn}, max {mx}"));
        }
        // The remainder lands on the first partitions, so counts never
        // increase along the worker axis.
        if counts.windows(2).any(|w| w[0] < w[1]) {
            return Err(format!("counts not non-increasing: {counts:?}"));
        }
        Ok(())
    });
}

#[test]
fn batch_smaller_than_workers_explicit() {
    for batch in 0..5usize {
        for extra in 1..5usize {
            let workers = batch + extra;
            let parts = partition_even(batch, workers);
            assert_eq!(parts.len(), workers);
            // The first `batch` workers get one feature, the rest none.
            for (w, p) in parts.iter().enumerate() {
                assert_eq!(p.worker, w);
                assert_eq!(p.count, usize::from(w < batch), "batch={batch} workers={workers}");
            }
            assert_eq!(parts.iter().map(|p| p.count).sum::<usize>(), batch);
        }
    }
}

#[test]
fn single_worker_takes_everything() {
    let parts = partition_even(123, 1);
    assert_eq!(parts.len(), 1);
    assert_eq!(parts[0].start, 0);
    assert_eq!(parts[0].count, 123);
}

#[test]
fn imbalance_of_uniform_work_is_one() {
    Runner::new(96, 0x1B1A).run("imbalance-uniform", |rng| {
        let n = proptest::usize_in(rng, 1, 32);
        let w = proptest::usize_in(rng, 0, 1000);
        let work = vec![w; n];
        let got = imbalance(&work);
        if (got - 1.0).abs() > 1e-12 {
            return Err(format!("imbalance({w} x {n}) = {got}, want 1.0"));
        }
        Ok(())
    });
}

#[test]
fn imbalance_is_at_least_one() {
    Runner::new(96, 0xC0DE).run("imbalance-lower-bound", |rng| {
        let n = proptest::usize_in(rng, 1, 24);
        let work: Vec<usize> = (0..n).map(|_| proptest::usize_in(rng, 0, 500)).collect();
        let got = imbalance(&work);
        // max/mean >= 1 whenever mean > 0; the all-zero case pins to 1.0.
        if got < 1.0 - 1e-12 {
            return Err(format!("imbalance({work:?}) = {got} < 1"));
        }
        Ok(())
    });
}

#[test]
fn imbalance_concentrated_work_equals_worker_count() {
    // One worker holds all the work: max/mean = n.
    for n in [1usize, 2, 5, 8] {
        let mut work = vec![0usize; n];
        work[0] = 700;
        assert!((imbalance(&work) - n as f64).abs() < 1e-12, "n={n}");
    }
}
