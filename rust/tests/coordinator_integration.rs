//! Integration: the full coordinator (partitioning, pruning, streaming,
//! multi-worker) over BOTH backends, validated against the challenge
//! ground truth — the production path end to end.

use std::path::PathBuf;

use spdnn::coordinator::{run_inference, validate, Backend, RunOptions};
use spdnn::data::Dataset;
use spdnn::util::config::RuntimeConfig;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts — run `make artifacts`");
        None
    }
}

/// 64-neuron config served by the toy artifact (capacity 8).
fn toy_cfg(workers: usize) -> RuntimeConfig {
    RuntimeConfig { neurons: 64, layers: 6, k: 4, batch: 20, workers, ..Default::default() }
}

/// Real challenge-width config served by the 1024-neuron artifacts.
fn challenge_cfg(batch: usize, layers: usize) -> RuntimeConfig {
    RuntimeConfig { neurons: 1024, layers, k: 32, batch, workers: 1, ..Default::default() }
}

#[test]
fn pjrt_backend_validates_toy_width() {
    let Some(dir) = artifacts_dir() else { return };
    let ds = Dataset::generate(&toy_cfg(1)).unwrap();
    let opts = RunOptions { backend: Backend::Pjrt { artifacts: dir }, ..Default::default() };
    let report = run_inference(&ds, &opts).unwrap();
    validate(&report, &ds).unwrap();
    // Capacity is 8 < 20 features, so at least layer 0 had to chunk
    // (3 dispatches), plus one dispatch per surviving layer.
    assert!(report.workers[0].dispatches > 6, "expected chunked dispatches");
}

#[test]
fn pjrt_backend_multi_worker() {
    let Some(dir) = artifacts_dir() else { return };
    let ds = Dataset::generate(&toy_cfg(3)).unwrap();
    let opts = RunOptions { backend: Backend::Pjrt { artifacts: dir }, ..Default::default() };
    let report = run_inference(&ds, &opts).unwrap();
    validate(&report, &ds).unwrap();
    assert_eq!(report.workers.len(), 3);
}

#[test]
fn pjrt_backend_challenge_width() {
    let Some(dir) = artifacts_dir() else { return };
    // 1024 neurons, RadiX-Net butterfly, challenge bias — a real (scaled)
    // challenge instance through the AOT kernel.
    let ds = Dataset::generate(&challenge_cfg(24, 4)).unwrap();
    let opts = RunOptions { backend: Backend::Pjrt { artifacts: dir }, ..Default::default() };
    let report = run_inference(&ds, &opts).unwrap();
    validate(&report, &ds).unwrap();
    assert!(report.edges_per_sec > 0.0);
}

#[test]
fn pjrt_with_streamed_weights() {
    let Some(dir) = artifacts_dir() else { return };
    let ds = Dataset::generate(&toy_cfg(2)).unwrap();
    let tmp = std::env::temp_dir().join(format!("spdnn_ci_{}", std::process::id()));
    ds.save(&tmp).unwrap();
    let opts = RunOptions {
        backend: Backend::Pjrt { artifacts: dir },
        stream_from: Some(tmp.join("weights.bin")),
        ..Default::default()
    };
    let report = run_inference(&ds, &opts).unwrap();
    validate(&report, &ds).unwrap();
}

#[test]
fn native_and_pjrt_agree_on_categories() {
    let Some(dir) = artifacts_dir() else { return };
    let ds = Dataset::generate(&toy_cfg(2)).unwrap();
    let native = run_inference(&ds, &RunOptions::default()).unwrap();
    let pjrt = run_inference(
        &ds,
        &RunOptions { backend: Backend::Pjrt { artifacts: dir }, ..Default::default() },
    )
    .unwrap();
    assert_eq!(native.categories, pjrt.categories);
}

#[test]
fn missing_artifact_width_is_clear_error() {
    let Some(dir) = artifacts_dir() else { return };
    let cfg = RuntimeConfig { neurons: 256, layers: 2, k: 4, batch: 4, ..Default::default() };
    let ds = Dataset::generate(&cfg).unwrap();
    let opts = RunOptions { backend: Backend::Pjrt { artifacts: dir }, ..Default::default() };
    let err = run_inference(&ds, &opts).unwrap_err();
    assert!(format!("{err:#}").contains("no layer_opt artifacts"), "{err:#}");
}
