//! Chaos and equivalence tests for the reactor serving engine: slowloris
//! eviction, abruptly-vanishing peers, a thousand idle connections that
//! must cost pollfds instead of threads, json-vs-binary wire identity,
//! and reactor-vs-threads engine identity on answers and error strings.

use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::{Duration, Instant};

use spdnn::cluster::WireFormat;
use spdnn::coordinator::batcher::{BatchPolicy, ServeBackend, ServedModel};
use spdnn::data::Dataset;
use spdnn::server::{
    Client, IoMode, ReferencePanel, Request, Server, ServerConfig, ServerHandle, WireResponse,
};
use spdnn::util::config::RuntimeConfig;
use spdnn::util::proptest::{self, Runner};

const NEURONS: usize = 64;

fn dataset() -> Dataset {
    let cfg = RuntimeConfig { neurons: NEURONS, layers: 4, k: 4, batch: 8, ..Default::default() };
    Dataset::generate(&cfg).unwrap()
}

fn start_io(ds: &Dataset, cfg: ServerConfig) -> ServerHandle {
    let reference = ReferencePanel { features: ds.features.clone(), neurons: NEURONS };
    Server::start(cfg, ServedModel::from_dataset(ds), ServeBackend::native(1, 12), Some(reference))
        .unwrap()
}

fn reactor_cfg() -> ServerConfig {
    ServerConfig {
        replicas: 1,
        policy: BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
        io: IoMode::Reactor,
        ..Default::default()
    }
}

/// The open-connection count the server reports through `{"op":"stats"}`.
fn connections(client: &mut Client) -> usize {
    match client.call(&Request::Stats).unwrap() {
        WireResponse::Stats(s) => s.req_usize("connections").unwrap(),
        other => panic!("stats verb failed: {other:?}"),
    }
}

#[test]
fn slowloris_is_evicted_while_service_continues() {
    let ds = dataset();
    let mut cfg = reactor_cfg();
    cfg.read_stall = Duration::from_millis(150);
    let handle = start_io(&ds, cfg);
    let addr = handle.addr();

    // The slowloris: drip half a request and go quiet. An *idle*
    // connection (no partial message) must survive the same window.
    let mut slow = TcpStream::connect(addr).unwrap();
    slow.write_all(b"{\"op\":\"inf").unwrap();
    let idle = TcpStream::connect(addr).unwrap();

    // A healthy client is served while both sit there.
    let mut client = Client::connect(addr).unwrap();
    assert!(matches!(client.call(&Request::infer_row(0)).unwrap(), WireResponse::Infer { .. }));

    // Past the read-stall deadline the reactor drops the connection:
    // the dripper's next read sees EOF (or a reset).
    slow.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut buf = [0u8; 64];
    match slow.read(&mut buf) {
        Ok(0) => {}
        Ok(n) => panic!("slowloris read {n} bytes instead of a close"),
        Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
            panic!("slowloris connection was never dropped")
        }
        Err(_) => {} // ECONNRESET: also dropped
    }

    // The idle connection is still usable after the sweep that killed
    // the dripper, and service is unaffected.
    let mut idle = idle;
    idle.write_all(b"{\"op\":\"ping\"}\n").unwrap();
    idle.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut one = [0u8; 1];
    assert_eq!(idle.read(&mut one).unwrap(), 1, "idle connection must outlive the stall sweep");
    assert!(matches!(client.call(&Request::infer_row(1)).unwrap(), WireResponse::Infer { .. }));
    handle.shutdown();
}

#[test]
fn vanishing_peers_leak_neither_connections_nor_service() {
    let ds = dataset();
    let handle = start_io(&ds, reactor_cfg());
    let addr = handle.addr();
    let mut client = Client::connect_wire(addr, WireFormat::Bin).unwrap();
    assert_eq!(client.wire(), WireFormat::Bin);
    let baseline = connections(&mut client);

    // Peers that vanish at every phase of the request cycle.
    for _ in 0..8 {
        // Connected, never spoke.
        drop(TcpStream::connect(addr).unwrap());
        // Half-open: FIN the write side without sending a byte.
        let s = TcpStream::connect(addr).unwrap();
        s.shutdown(std::net::Shutdown::Write).unwrap();
        drop(s);
        // Request sent, gone before the response could be written.
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(b"{\"op\":\"infer\",\"row\":0}\n").unwrap();
        drop(s);
    }

    // The reactor reaps them all; the gauge returns to baseline.
    let t0 = Instant::now();
    loop {
        if connections(&mut client) <= baseline {
            break;
        }
        assert!(t0.elapsed() < Duration::from_secs(10), "vanished peers were never reaped");
        std::thread::sleep(Duration::from_millis(25));
    }

    // Live traffic still flows on the negotiated binary wire.
    assert!(matches!(client.call(&Request::infer_row(0)).unwrap(), WireResponse::Infer { .. }));
    handle.shutdown();
}

/// `Threads:` from /proc/self/status (Linux; None elsewhere).
fn os_thread_count() -> Option<usize> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    status
        .lines()
        .find(|l| l.starts_with("Threads:"))?
        .split_whitespace()
        .nth(1)?
        .parse()
        .ok()
}

#[test]
fn a_thousand_idle_connections_cost_no_threads() {
    let before_probe = os_thread_count();
    if before_probe.is_none() {
        eprintln!("skipping: /proc/self/status not readable on this platform");
        return;
    }

    let ds = dataset();
    let mut cfg = reactor_cfg();
    cfg.max_conns = 1500;
    let handle = start_io(&ds, cfg);
    let addr = handle.addr();

    // Steady state first so replica/reactor threads are all started.
    let mut client = Client::connect_wire(addr, WireFormat::Bin).unwrap();
    assert!(matches!(client.call(&Request::infer_row(0)).unwrap(), WireResponse::Infer { .. }));
    let before = os_thread_count().unwrap();

    let idle: Vec<TcpStream> = (0..1000).map(|_| TcpStream::connect(addr).unwrap()).collect();

    // Live traffic threads through the idle crowd.
    for i in 0..8 {
        assert!(matches!(
            client.call(&Request::infer_row(i % ds.cfg.batch)).unwrap(),
            WireResponse::Infer { .. }
        ));
    }
    let during = os_thread_count().unwrap();
    assert!(
        during <= before + 4,
        "idle connections must cost pollfds, not threads: {before} -> {during}"
    );
    // The server sees the whole crowd (1000 idle + this client).
    assert!(connections(&mut client) > 1000, "connection gauge missed the idle crowd");

    drop(idle);
    handle.shutdown();
}

#[test]
fn json_and_binary_wires_answer_bit_identically() {
    let ds = dataset();
    let handle = start_io(&ds, reactor_cfg());
    let addr = handle.addr();
    let mut json = Client::connect(addr).unwrap();
    let mut bin = Client::connect_wire(addr, WireFormat::Bin).unwrap();
    assert_eq!(json.wire(), WireFormat::Json);
    assert_eq!(bin.wire(), WireFormat::Bin, "a v2 server must accept the hello");

    Runner::new(48, 0xB17).run("json-vs-bin-wire-identity", |rng| {
        let feats = proptest::vec_f32(rng, NEURONS, 0.0, 1.0);
        let req = Request::infer_features(feats);
        let a = json.call(&req).map_err(|e| format!("json call: {e:#}"))?;
        let b = bin.call(&req).map_err(|e| format!("bin call: {e:#}"))?;
        match (a, b) {
            (
                WireResponse::Infer { active: aa, activations: va, .. },
                WireResponse::Infer { active: ab, activations: vb, .. },
            ) => {
                if aa != ab {
                    return Err(format!("active flag diverges: json={aa} bin={ab}"));
                }
                let va = va.ok_or("json response dropped activations")?;
                let vb = vb.ok_or("bin response dropped activations")?;
                if va.len() != vb.len()
                    || va.iter().zip(&vb).any(|(x, y)| x.to_bits() != y.to_bits())
                {
                    return Err("activations diverge between the wires".to_string());
                }
                Ok(())
            }
            other => Err(format!("non-infer response pair: {other:?}")),
        }
    });
    handle.shutdown();
}

/// One raw request against `addr`; returns everything up to and
/// including the first newline of the response.
fn raw_response_line(addr: SocketAddr, payload: &[u8]) -> Vec<u8> {
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(payload).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut buf = Vec::new();
    let mut chunk = [0u8; 1024];
    loop {
        match s.read(&mut chunk) {
            Ok(0) => break,
            Ok(n) => {
                buf.extend_from_slice(&chunk[..n]);
                if let Some(pos) = buf.iter().position(|&b| b == b'\n') {
                    buf.truncate(pos + 1);
                    break;
                }
            }
            Err(e) => panic!("raw read from {addr}: {e}"),
        }
    }
    buf
}

#[test]
fn reactor_and_threads_engines_answer_identically() {
    let ds = dataset();
    let mk = |io: IoMode| {
        let mut cfg = reactor_cfg();
        cfg.io = io;
        start_io(&ds, cfg)
    };
    let threads = mk(IoMode::Threads);
    let reactor = mk(IoMode::Reactor);
    let mut ct = Client::connect_wire(threads.addr(), WireFormat::Bin).unwrap();
    let mut cr = Client::connect_wire(reactor.addr(), WireFormat::Bin).unwrap();
    assert_eq!(ct.wire(), WireFormat::Bin);
    assert_eq!(cr.wire(), WireFormat::Bin);

    // Happy path: bit-identical activations row by row (the same seed
    // generated the same dataset behind both servers).
    for i in 0..ds.cfg.batch {
        let a = ct.call(&Request::infer_row(i)).unwrap();
        let b = cr.call(&Request::infer_row(i)).unwrap();
        match (a, b) {
            (
                WireResponse::Infer { active: aa, activations: va, .. },
                WireResponse::Infer { active: ab, activations: vb, .. },
            ) => {
                assert_eq!(aa, ab, "row {i}: active flag diverges");
                let (va, vb) = (va.unwrap(), vb.unwrap());
                assert_eq!(va.len(), vb.len(), "row {i}");
                assert!(
                    va.iter().zip(&vb).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "row {i}: activations diverge between engines"
                );
            }
            other => panic!("row {i}: non-infer response pair {other:?}"),
        }
    }

    // Deterministic error paths: the strings must match byte for byte.
    for req in [Request::infer_row(999), Request::infer_features(vec![0.0; 3])] {
        let a = ct.call(&req).unwrap();
        let b = cr.call(&req).unwrap();
        match (a, b) {
            (WireResponse::Error { message: ma }, WireResponse::Error { message: mb }) => {
                assert_eq!(ma, mb, "error strings diverge between engines");
            }
            other => panic!("expected an error pair, got {other:?}"),
        }
    }

    // A malformed line gets the identical raw error bytes from both.
    let a = raw_response_line(threads.addr(), b"this is not json\n");
    let b = raw_response_line(reactor.addr(), b"this is not json\n");
    assert!(!a.is_empty(), "threads engine answered nothing to a malformed line");
    assert_eq!(
        String::from_utf8_lossy(&a),
        String::from_utf8_lossy(&b),
        "malformed-line responses diverge between engines"
    );

    // Control verbs agree too (ping is timing-free).
    assert_eq!(ct.call(&Request::Ping).unwrap(), cr.call(&Request::Ping).unwrap());

    threads.shutdown();
    reactor.shutdown();
}
