//! Integration: the AOT HLO artifacts actually load, compile and execute
//! through the PJRT CPU client, and their numerics match the native
//! engines — the end-to-end proof of the three-layer architecture.
//!
//! Needs `make artifacts` (skips with a notice otherwise).

use std::path::PathBuf;

use spdnn::engine::EllEngine;
use spdnn::formats::EllMatrix;
use spdnn::radixnet::{RadixNet, Topology};
use spdnn::runtime::{Kind, LayerLiterals, Manifest, PjrtBackend};
use spdnn::util::prng::Xoshiro256;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts/manifest.json — run `make artifacts` first");
        None
    }
}

/// Toy problem matching the layer_toy_n64_c8 artifact.
fn toy_problem(seed: u64) -> (EllMatrix, Vec<f32>, Vec<f32>) {
    let net = RadixNet::new(64, 1, 4, Topology::Random, seed).unwrap();
    let mut w = net.layer_ell(0);
    let mut rng = Xoshiro256::new(seed ^ 0xF00D);
    for v in w.value.iter_mut() {
        *v = rng.next_range_f32(-0.4, 0.4);
    }
    let bias: Vec<f32> = (0..64).map(|_| rng.next_range_f32(-0.2, 0.05)).collect();
    let y: Vec<f32> = (0..8 * 64).map(|_| if rng.next_f32() < 0.3 { 1.0 } else { 0.0 }).collect();
    (w, bias, y)
}

#[test]
fn toy_artifact_matches_native_engine() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let artifact = manifest
        .artifacts
        .iter()
        .find(|a| a.kind == Kind::LayerToy)
        .expect("toy artifact present");
    let backend = PjrtBackend::cpu().unwrap();
    let exe = backend.compile(artifact).unwrap();

    for seed in [1u64, 2, 3] {
        let (w, bias, y) = toy_problem(seed);
        let lits = LayerLiterals::new(&w.index, &w.value, &bias, 64, 4).unwrap();
        let out = exe.run(&y, &lits).unwrap();

        let mut want = vec![0.0f32; y.len()];
        EllEngine::new(1).layer(&w, &bias, &y, &mut want);
        assert_eq!(out.y_next.len(), want.len());
        for (i, (a, b)) in out.y_next.iter().zip(&want).enumerate() {
            assert!((a - b).abs() < 1e-4, "seed {seed} elem {i}: pjrt {a} vs native {b}");
        }
        // Activity flags agree with the panel contents.
        for f in 0..8 {
            let any = want[f * 64..(f + 1) * 64].iter().any(|&v| v > 0.0);
            assert_eq!(out.active[f] != 0, any, "seed {seed} feature {f}");
        }
    }
}

#[test]
fn short_panel_is_zero_padded() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let artifact = manifest.artifacts.iter().find(|a| a.kind == Kind::LayerToy).unwrap();
    let backend = PjrtBackend::cpu().unwrap();
    let exe = backend.compile(artifact).unwrap();

    let (w, bias, y) = toy_problem(9);
    let lits = LayerLiterals::new(&w.index, &w.value, &bias, 64, 4).unwrap();
    // Only 3 of 8 capacity rows provided.
    let out = exe.run(&y[..3 * 64], &lits).unwrap();
    let mut want = vec![0.0f32; 3 * 64];
    EllEngine::new(1).layer(&w, &bias, &y[..3 * 64], &mut want);
    for (a, b) in out.y_next[..3 * 64].iter().zip(&want) {
        assert!((a - b).abs() < 1e-4);
    }
    // Padded rows: bias is negative so activations and flags are zero.
    assert!(out.y_next[3 * 64..].iter().all(|&v| v >= 0.0));
    assert!(out.active[3..].iter().all(|&f| f == 0 || bias.iter().any(|&b| b > 0.0)));
}

#[test]
fn run_rejects_bad_shapes() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let artifact = manifest.artifacts.iter().find(|a| a.kind == Kind::LayerToy).unwrap();
    let backend = PjrtBackend::cpu().unwrap();
    let exe = backend.compile(artifact).unwrap();
    let (w, bias, y) = toy_problem(4);
    let lits = LayerLiterals::new(&w.index, &w.value, &bias, 64, 4).unwrap();
    // Oversized panel.
    let big = vec![0.0f32; 9 * 64];
    assert!(exe.run(&big, &lits).is_err());
    // Non-multiple of neurons.
    assert!(exe.run(&y[..65], &lits).is_err());
    // Mismatched weights.
    let bad =
        LayerLiterals::new(&w.index[..32 * 4], &w.value[..32 * 4], &bias[..32], 32, 4).unwrap();
    assert!(exe.run(&y, &bad).is_err());
}

#[test]
fn manifest_loads_real_artifacts() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    assert_eq!(manifest.relu_cap, 32.0);
    assert!(!manifest.capacity_ladder(1024).is_empty());
    for a in &manifest.artifacts {
        assert!(a.path.exists(), "{} missing", a.path.display());
    }
}
