//! Shared helpers for the integration-test binaries. Each test binary
//! compiles its own copy (`mod common;`), so not every helper is used
//! by every binary.
#![allow(dead_code)]

pub mod chaos;
