//! A fault-injection TCP proxy for the cluster wire protocol.
//!
//! The proxy sits between a coordinator (rank 0 / a serving replica)
//! and one worker rank, understands the protocol's message boundaries —
//! JSON lines and `spdnn-clu1` binary frames, told apart by the first
//! byte — and can delay, truncate, corrupt or sever the
//! coordinator→worker stream on a chosen message. The worker→
//! coordinator direction is piped verbatim, so a fault always models
//! something happening to the *request* path of one rank.
//!
//! Faults are installed at runtime with [`ChaosProxy::set_fault`], so a
//! test can bring a cluster up cleanly (hello/load untouched) and then
//! break exactly the message it wants to break. Message indices are
//! global across the proxy's lifetime ([`ChaosProxy::messages`] reads
//! the current count).

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// What the proxy does to the coordinator→worker stream.
#[derive(Clone, Copy, Debug)]
pub enum Fault {
    /// Forward everything untouched.
    None,
    /// Hold every message from index `after` onwards for `delay`
    /// before forwarding it (a stalled rank: the connection lives, the
    /// bytes just do not arrive).
    Delay { after: usize, delay: Duration },
    /// Forward messages before index `after`, then shut both stream
    /// halves down (a severed rank: connection reset mid-protocol).
    Sever { after: usize },
    /// Forward only the first `keep` bytes of message `index`, then
    /// sever (a truncated frame: the peer sees a half message + EOF).
    Truncate { index: usize, keep: usize },
    /// Flip one byte of message `index`'s leading metadata (a
    /// corrupted frame: framing survives, but the message fails
    /// protocol-level validation). For a binary frame the flipped byte
    /// is the first payload word — a shard's `start` — so the worker
    /// echoes a range the gather's cover checks must reject; for a
    /// JSON line it is an early structural character, so parsing
    /// fails. Deliberately NOT a mid-panel f32 byte: that would be
    /// silent data corruption no protocol layer can see.
    Corrupt { index: usize },
}

/// One listening fault proxy in front of one worker-rank address.
pub struct ChaosProxy {
    addr: SocketAddr,
    fault: Arc<Mutex<Fault>>,
    messages: Arc<AtomicUsize>,
    stop: Arc<AtomicBool>,
}

impl ChaosProxy {
    /// Listen on a fresh loopback port, forwarding to `target`.
    pub fn start(target: SocketAddr) -> ChaosProxy {
        ChaosProxy::start_with(target, Fault::None)
    }

    pub fn start_with(target: SocketAddr, fault: Fault) -> ChaosProxy {
        let listener = TcpListener::bind("127.0.0.1:0").expect("binding chaos proxy");
        let addr = listener.local_addr().expect("proxy address");
        listener.set_nonblocking(true).expect("nonblocking proxy listener");
        let fault = Arc::new(Mutex::new(fault));
        let messages = Arc::new(AtomicUsize::new(0));
        let stop = Arc::new(AtomicBool::new(false));
        {
            let fault = fault.clone();
            let messages = messages.clone();
            let stop = stop.clone();
            std::thread::spawn(move || accept_loop(listener, target, fault, messages, stop));
        }
        ChaosProxy { addr, fault, messages, stop }
    }

    /// The address a coordinator should connect to instead of the rank.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Swap the active fault (applies to the next message read).
    pub fn set_fault(&self, fault: Fault) {
        *self.fault.lock().expect("fault lock") = fault;
    }

    /// Coordinator→worker messages seen so far (all connections).
    pub fn messages(&self) -> usize {
        self.messages.load(Ordering::SeqCst)
    }
}

impl Drop for ChaosProxy {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Release);
    }
}

fn accept_loop(
    listener: TcpListener,
    target: SocketAddr,
    fault: Arc<Mutex<Fault>>,
    messages: Arc<AtomicUsize>,
    stop: Arc<AtomicBool>,
) {
    loop {
        if stop.load(Ordering::Acquire) {
            return;
        }
        match listener.accept() {
            Ok((client, _)) => {
                let fault = fault.clone();
                let messages = messages.clone();
                std::thread::spawn(move || forward(client, target, fault, messages));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => return,
        }
    }
}

fn forward(
    client: TcpStream,
    target: SocketAddr,
    fault: Arc<Mutex<Fault>>,
    messages: Arc<AtomicUsize>,
) {
    let Ok(upstream) = TcpStream::connect(target) else {
        let _ = client.shutdown(Shutdown::Both);
        return;
    };
    client.set_nodelay(true).ok();
    upstream.set_nodelay(true).ok();
    // Worker→coordinator: verbatim pipe on its own thread.
    {
        let Ok(up_read) = upstream.try_clone() else { return };
        let Ok(down_write) = client.try_clone() else { return };
        std::thread::spawn(move || pipe_raw(up_read, down_write));
    }
    // Coordinator→worker: message-framed, fault-aware.
    let mut writer = upstream;
    let mut reader = BufReader::new(client);
    loop {
        let mut msg = match read_message(&mut reader) {
            Some(m) if !m.is_empty() => m,
            _ => break,
        };
        let index = messages.fetch_add(1, Ordering::SeqCst);
        let f = *fault.lock().expect("fault lock");
        match f {
            Fault::None => {}
            Fault::Delay { after, delay } => {
                if index >= after {
                    std::thread::sleep(delay);
                }
            }
            Fault::Sever { after } => {
                if index >= after {
                    break;
                }
            }
            Fault::Truncate { index: at, keep } => {
                if index == at {
                    let keep = keep.min(msg.len());
                    let _ = writer.write_all(&msg[..keep]);
                    let _ = writer.flush();
                    break;
                }
            }
            Fault::Corrupt { index: at } => {
                if index == at {
                    let flip = if msg[0] == b'S' && msg.len() > 9 { 9 } else { 2 };
                    msg[flip.min(msg.len() - 1)] ^= 0x55;
                }
            }
        }
        if writer.write_all(&msg).is_err() || writer.flush().is_err() {
            break;
        }
    }
    let _ = writer.shutdown(Shutdown::Both);
    let _ = reader.get_ref().shutdown(Shutdown::Both);
}

fn pipe_raw(mut from: TcpStream, mut to: TcpStream) {
    let mut buf = [0u8; 8192];
    loop {
        match from.read(&mut buf) {
            Ok(0) | Err(_) => break,
            Ok(n) => {
                if to.write_all(&buf[..n]).is_err() || to.flush().is_err() {
                    break;
                }
            }
        }
    }
    let _ = to.shutdown(Shutdown::Both);
    let _ = from.shutdown(Shutdown::Both);
}

/// Read one protocol message off the stream: a `spdnn-clu1` frame when
/// the first byte is the magic's `S`, a newline-terminated JSON line
/// otherwise. Returns `None` on EOF or a broken stream.
fn read_message(r: &mut BufReader<TcpStream>) -> Option<Vec<u8>> {
    let first = {
        let buf = r.fill_buf().ok()?;
        if buf.is_empty() {
            return None;
        }
        buf[0]
    };
    if first == b'S' {
        // magic(4) + kind(1) + u32 len(4), then the payload.
        let mut header = [0u8; 9];
        r.read_exact(&mut header).ok()?;
        let len = u32::from_le_bytes([header[5], header[6], header[7], header[8]]) as usize;
        let mut msg = Vec::with_capacity(9 + len);
        msg.extend_from_slice(&header);
        let mut payload = vec![0u8; len];
        r.read_exact(&mut payload).ok()?;
        msg.extend_from_slice(&payload);
        Some(msg)
    } else {
        let mut line = Vec::new();
        let n = r.read_until(b'\n', &mut line).ok()?;
        if n == 0 {
            return None;
        }
        Some(line)
    }
}
